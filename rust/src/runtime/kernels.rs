//! Tiled, multi-threaded compute kernels for the native backend.
//!
//! Every kernel here is **bit-identical** to its scalar reference (`*_ref`,
//! the PR-1 single-threaded triple loops, kept verbatim below as the
//! executable specification) at any thread count. The determinism contract:
//!
//! - **Row-partitioned parallelism.** Work is split by *disjoint contiguous
//!   output-row ranges*; each output element is written by exactly one
//!   thread. There are no parallel reductions and no atomics.
//! - **Sequential inner accumulation.** Within one output element, the f32
//!   additions happen in exactly the scalar kernel's order (ascending `k` /
//!   ascending reduction row). Cache tiling only reorders *which element*
//!   is advanced next, never the addition sequence *inside* an element.
//! - **Identical zero-skipping.** The scalar kernels skip zero left-operand
//!   entries (banded adjacency operators are mostly structural zeros); the
//!   tiled kernels skip the same entries, so the executed FLOP sequence per
//!   element matches term for term.
//!
//! Consequently the sync-mode bit-parity assertions between the sequential
//! driver and the cluster engine hold at *any* `kernel_threads` setting —
//! including mixed settings across engines (see `tests/kernels.rs`).
//!
//! On top of the three matmul shapes the layer adds:
//!
//! - **banded-adjacency kernels** ([`matmul_banded`], [`matmul_at_b_banded`])
//!   for the sampler's block operators `A1`/`A2`, whose row `i` can only
//!   hold non-zeros in the slot band `[i*f, (i+1)*f)` (see
//!   `sampler::BlockBuilder`). The dense scalar kernel scans and skips every
//!   structural zero; the banded kernels touch only the band — the same
//!   O(nnz) work the Pallas aggregation kernels do on device — while
//!   executing the identical addition sequence.
//! - **fused epilogues** ([`linear`]): bias add + ReLU run inside the same
//!   parallel row pass as the matmul, while the output rows are cache-hot.
//!
//! Dispatch: every public kernel takes a [`KernelCtx`]. `ctx.scalar()`
//! forces the reference path (benchmark baseline, parity tests); otherwise
//! the tiled body runs, engaging the [`ThreadPool`] only when the call is
//! large enough to amortize the dispatch (two channel hops per worker).

use std::sync::Arc;

use super::pool::ThreadPool;

/// Reduction-dimension tile: the `[K_TILE x n]` panel of the right operand
/// stays cache-resident while a row range streams over it.
const K_TILE: usize = 256;

/// Minimum multiply-accumulate count before a kernel engages the pool;
/// below this the dispatch overhead dominates and the call runs inline on
/// the caller (still tiled). Tiny-dataset steps stay single-threaded.
const MIN_PAR_FLOPS: usize = 1 << 14;

/// Kernel execution context: the worker pool plus the scalar-fallback flag.
/// Cheap to clone (the pool is shared).
#[derive(Clone)]
pub struct KernelCtx {
    pool: Arc<ThreadPool>,
    scalar: bool,
}

impl KernelCtx {
    /// Context over a fresh pool of `threads` lanes (0 = host cores).
    pub fn new(threads: usize) -> KernelCtx {
        KernelCtx {
            pool: Arc::new(ThreadPool::new(threads)),
            scalar: false,
        }
    }

    /// Context over an existing (shared) pool.
    pub fn with_pool(pool: Arc<ThreadPool>, scalar: bool) -> KernelCtx {
        KernelCtx { pool, scalar }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// True when the scalar reference kernels are forced.
    pub fn scalar(&self) -> bool {
        self.scalar
    }
}

/// Output base pointer crossing into pool lanes; each lane derives its own
/// disjoint index range from it. Shared by every parallel pass in the crate
/// (`par_rows`/`par_ranges` here, the loss-grad rows in `runtime::native`,
/// the serve-cache aggregation) so the soundness argument lives in exactly
/// one place.
pub(crate) struct SendMut(pub(crate) *mut f32);
// SAFETY: lanes write disjoint ranges (see `par_rows`/`par_ranges`), and
// the borrow outlives the pool dispatch, which blocks until every lane is
// done.
unsafe impl Send for SendMut {}
unsafe impl Sync for SendMut {}

/// Run `body(lo, hi, out_rows)` over disjoint contiguous row ranges of
/// `out` (`rows` rows of length `n`), on the pool when `flops` is large
/// enough, inline otherwise. `out_rows` is exactly `out[lo*n .. hi*n]`.
fn par_rows(
    ctx: &KernelCtx,
    out: &mut [f32],
    rows: usize,
    n: usize,
    flops: usize,
    body: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(out.len(), rows * n);
    let lanes = ctx.pool.threads().min(rows.max(1));
    if lanes <= 1 || flops < MIN_PAR_FLOPS {
        body(0, rows, out);
        return;
    }
    let chunk = rows.div_ceil(lanes);
    let base = SendMut(out.as_mut_ptr());
    ctx.pool.run(&|lane| {
        let lo = lane * chunk;
        if lo >= rows {
            return;
        }
        let hi = (lo + chunk).min(rows);
        // SAFETY: [lo, hi) row ranges are disjoint across lanes and
        // in-bounds; `ThreadPool::run` blocks until every lane returns,
        // so the `out` borrow outlives all writes.
        let out_rows =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(lo * n), (hi - lo) * n) };
        body(lo, hi, out_rows);
    });
}

/// Run `body(lo, hi)` over disjoint contiguous ranges partitioning
/// `0..rows` — on the pool when `flops` is large enough, inline otherwise.
/// The generic range dispatcher behind the elementwise passes (optimizer
/// updates, loss-gradient rows, serve-cache aggregation): any computation
/// whose unit `i` writes only unit-`i` outputs is bit-identical at every
/// lane count under it, because each unit runs exactly once on exactly one
/// lane and its internal op order is untouched.
pub fn par_ranges(ctx: &KernelCtx, rows: usize, flops: usize, body: impl Fn(usize, usize) + Sync) {
    let lanes = ctx.pool.threads().min(rows.max(1));
    if lanes <= 1 || flops < MIN_PAR_FLOPS {
        body(0, rows);
        return;
    }
    let chunk = rows.div_ceil(lanes);
    ctx.pool.run(&|lane| {
        let lo = lane * chunk;
        if lo >= rows {
            return;
        }
        let hi = (lo + chunk).min(rows);
        body(lo, hi);
    });
}

// ---------------------------------------------------------------------------
// scalar reference kernels (bit-exact specification; also the bench baseline)
// ---------------------------------------------------------------------------

/// `out[m,n] = a[m,k] @ b[k,n]`, skipping zero entries of `a` — the scalar
/// reference every tiled kernel must reproduce bit-for-bit.
pub fn matmul_ref(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,n] (+)= a[r,m]ᵀ @ b[r,n]`; zeroes `out` first unless `acc`
/// (scalar reference).
#[allow(clippy::too_many_arguments)]
pub fn matmul_at_b_ref(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    r: usize,
    m: usize,
    n: usize,
    acc: bool,
) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    debug_assert_eq!(out.len(), m * n);
    if !acc {
        out.fill(0.0);
    }
    for row in 0..r {
        let arow = &a[row * m..(row + 1) * m];
        let brow = &b[row * n..(row + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,n] = a[m,k] @ b[n,k]ᵀ` (row-by-row dot products; scalar
/// reference).
pub fn matmul_a_bt_ref(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                s += x * y;
            }
            out[i * n + j] = s;
        }
    }
}

// ---------------------------------------------------------------------------
// elementwise helpers (order-free; shared by both paths)
// ---------------------------------------------------------------------------

/// `out[r,n] += bias[n]` broadcast over rows.
pub fn add_bias(out: &mut [f32], bias: &[f32], r: usize, n: usize) {
    debug_assert_eq!(out.len(), r * n);
    debug_assert_eq!(bias.len(), n);
    for row in 0..r {
        for (o, &bv) in out[row * n..(row + 1) * n].iter_mut().zip(bias) {
            *o += bv;
        }
    }
}

pub fn relu_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// `dz = dh ⊙ (h > 0)` in place on `dh` (relu backward; `h` is post-act).
pub fn relu_backward_inplace(dh: &mut [f32], h: &[f32]) {
    for (d, &hv) in dh.iter_mut().zip(h) {
        if hv <= 0.0 {
            *d = 0.0;
        }
    }
}

/// `out[n] (+)= column sums of g[r,n]` — row-ascending accumulation; kept
/// sequential (n is a class/hidden width here, far below the parallel
/// threshold, and splitting rows would change the addition order).
pub fn colsum(g: &[f32], out: &mut [f32], r: usize, n: usize, acc: bool) {
    debug_assert_eq!(g.len(), r * n);
    debug_assert_eq!(out.len(), n);
    if !acc {
        out.fill(0.0);
    }
    for row in 0..r {
        for (o, &gv) in out.iter_mut().zip(&g[row * n..(row + 1) * n]) {
            *o += gv;
        }
    }
}

// ---------------------------------------------------------------------------
// tiled + parallel kernels
// ---------------------------------------------------------------------------

/// Tiled body shared by [`matmul`] and [`linear`]: rows `[lo, hi)` of
/// `a @ b`, k-tiled so the active `b` panel stays cache-resident. Per
/// output element the additions run over ascending `k` (tiles ascending,
/// ascending within a tile) — the scalar order.
fn matmul_rows(a: &[f32], b: &[f32], out_rows: &mut [f32], lo: usize, hi: usize, k: usize, n: usize) {
    out_rows.fill(0.0);
    for k0 in (0..k).step_by(K_TILE) {
        let k1 = (k0 + K_TILE).min(k);
        for i in lo..hi {
            let arow = &a[i * k + k0..i * k + k1];
            let orow = &mut out_rows[(i - lo) * n..(i - lo + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[(k0 + kk) * n..(k0 + kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// `out[m,n] = a[m,k] @ b[k,n]` — tiled, parallel by output-row ranges;
/// bit-identical to [`matmul_ref`] at any thread count.
pub fn matmul(ctx: &KernelCtx, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    let _s = crate::obs::span("kernel.matmul");
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if ctx.scalar {
        return matmul_ref(a, b, out, m, k, n);
    }
    par_rows(ctx, out, m, n, m * k * n, |lo, hi, out_rows| {
        matmul_rows(a, b, out_rows, lo, hi, k, n);
    });
}

/// [`matmul`] for a banded left operand: row `i`'s non-zeros lie entirely in
/// columns `[i*band, (i+1)*band)` (the block builder's slot-group bands, so
/// `k == m * band`). Touches only the band — O(nnz) instead of an O(m·k)
/// zero scan — and is bit-identical to [`matmul_ref`] on such operands: the
/// skipped columns are structural zeros the dense kernel skips too, and the
/// band is walked in the same ascending-`k` order.
#[allow(clippy::too_many_arguments)]
pub fn matmul_banded(
    ctx: &KernelCtx,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    band: usize,
) {
    let _s = crate::obs::span("kernel.matmul_banded");
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    assert_eq!(m * band, k, "banded matmul: k must equal m * band");
    if ctx.scalar {
        return matmul_ref(a, b, out, m, k, n);
    }
    par_rows(ctx, out, m, n, m * band * n, |lo, hi, out_rows| {
        for i in lo..hi {
            let orow = &mut out_rows[(i - lo) * n..(i - lo + 1) * n];
            orow.fill(0.0);
            for kk in i * band..(i + 1) * band {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
}

/// `out[m,n] (+)= a[r,m]ᵀ @ b[r,n]` — parallel by output-row ranges. The
/// reduction row loop stays ascending per element (r-tiles ascending,
/// ascending within), so results match [`matmul_at_b_ref`] bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn matmul_at_b(
    ctx: &KernelCtx,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    r: usize,
    m: usize,
    n: usize,
    acc: bool,
) {
    let _s = crate::obs::span("kernel.matmul_at_b");
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    debug_assert_eq!(out.len(), m * n);
    if ctx.scalar {
        return matmul_at_b_ref(a, b, out, r, m, n, acc);
    }
    par_rows(ctx, out, m, n, r * m * n, |lo, hi, out_rows| {
        if !acc {
            out_rows.fill(0.0);
        }
        for r0 in (0..r).step_by(K_TILE) {
            let r1 = (r0 + K_TILE).min(r);
            for i in lo..hi {
                let orow = &mut out_rows[(i - lo) * n..(i - lo + 1) * n];
                for row in r0..r1 {
                    let av = a[row * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[row * n..(row + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
}

/// [`matmul_at_b`] for a banded `a` (see [`matmul_banded`]; here
/// `m == r * band`): output row `i` receives exactly one contribution,
/// `a[i/band, i] * b[i/band, :]` — the backward pass of the slot-band
/// aggregation. Bit-identical to [`matmul_at_b_ref`] on banded operands
/// (every other reduction row holds a structural zero at column `i`).
#[allow(clippy::too_many_arguments)]
pub fn matmul_at_b_banded(
    ctx: &KernelCtx,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    r: usize,
    m: usize,
    n: usize,
    band: usize,
    acc: bool,
) {
    let _s = crate::obs::span("kernel.matmul_at_b_banded");
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    debug_assert_eq!(out.len(), m * n);
    assert_eq!(r * band, m, "banded matmul_at_b: m must equal r * band");
    if ctx.scalar {
        return matmul_at_b_ref(a, b, out, r, m, n, acc);
    }
    par_rows(ctx, out, m, n, m * n, |lo, hi, out_rows| {
        for i in lo..hi {
            let orow = &mut out_rows[(i - lo) * n..(i - lo + 1) * n];
            if !acc {
                orow.fill(0.0);
            }
            let row = i / band;
            let av = a[row * m + i];
            if av == 0.0 {
                continue;
            }
            let brow = &b[row * n..(row + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    });
}

/// `out[m,n] = a[m,k] @ b[n,k]ᵀ` — parallel by output rows; each element is
/// one full-length sequential dot product, exactly as in
/// [`matmul_a_bt_ref`].
pub fn matmul_a_bt(
    ctx: &KernelCtx,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let _s = crate::obs::span("kernel.matmul_a_bt");
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if ctx.scalar {
        return matmul_a_bt_ref(a, b, out, m, k, n);
    }
    par_rows(ctx, out, m, n, m * k * n, |lo, hi, out_rows| {
        for i in lo..hi {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out_rows[(i - lo) * n..(i - lo + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for (&x, &y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                *o = s;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// parallel elementwise passes (optimizer updates)
// ---------------------------------------------------------------------------
//
// Parameter updates are elementwise: element `i` of the output depends only
// on element `i` of the inputs, with no cross-element reduction. Splitting
// the index space over disjoint lane ranges therefore keeps every result
// bit-identical to the sequential loop at any thread count — the easiest
// case of the determinism contract. The scalar flag still routes to the
// plain sequential loop (the executable specification / bench baseline).

/// SGD step `p[i] -= lr * g[i]`, parallelized over disjoint index ranges.
pub fn sgd_update(ctx: &KernelCtx, p: &mut [f32], g: &[f32], lr: f32) {
    let _s = crate::obs::span("kernel.sgd_update");
    debug_assert_eq!(p.len(), g.len());
    if ctx.scalar {
        for (pv, &gv) in p.iter_mut().zip(g) {
            *pv -= lr * gv;
        }
        return;
    }
    let n = p.len();
    let base = SendMut(p.as_mut_ptr());
    par_ranges(ctx, n, n, |lo, hi| {
        // SAFETY: [lo, hi) index ranges are disjoint across lanes and
        // in-bounds; par_ranges blocks until every lane returns.
        let ps = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        for (pv, &gv) in ps.iter_mut().zip(&g[lo..hi]) {
            *pv -= lr * gv;
        }
    });
}

/// Bias-corrected Adam step on one tensor's flat data, parallelized over
/// disjoint index ranges. `bc1`/`bc2` are the step's bias corrections
/// `1 - β1^t` / `1 - β2^t` (the `t` counter stays with the caller). The
/// per-element op sequence is exactly the sequential reference's:
/// `m = β1·m + (1−β1)·g; v = β2·v + (1−β2)·g²; p -= lr·m̂/(√v̂ + ε)`.
#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    ctx: &KernelCtx,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
    b1: f32,
    b2: f32,
    eps: f32,
) {
    let _s = crate::obs::span("kernel.adam_update");
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(p.len(), m.len());
    debug_assert_eq!(p.len(), v.len());
    let step = |ps: &mut [f32], ms: &mut [f32], vs: &mut [f32], gs: &[f32]| {
        for (((pv, &gv), mv), vv) in ps.iter_mut().zip(gs).zip(ms.iter_mut()).zip(vs.iter_mut())
        {
            *mv = b1 * *mv + (1.0 - b1) * gv;
            *vv = b2 * *vv + (1.0 - b2) * gv * gv;
            let mhat = *mv / bc1;
            let vhat = *vv / bc2;
            *pv -= lr * mhat / (vhat.sqrt() + eps);
        }
    };
    if ctx.scalar {
        step(p, m, v, g);
        return;
    }
    let n = p.len();
    let (bp, bm, bv) = (
        SendMut(p.as_mut_ptr()),
        SendMut(m.as_mut_ptr()),
        SendMut(v.as_mut_ptr()),
    );
    par_ranges(ctx, n, n * 4, |lo, hi| {
        // SAFETY: disjoint in-bounds index ranges per lane; par_ranges
        // blocks until every lane returns (see sgd_update).
        let ps = unsafe { std::slice::from_raw_parts_mut(bp.0.add(lo), hi - lo) };
        let ms = unsafe { std::slice::from_raw_parts_mut(bm.0.add(lo), hi - lo) };
        let vs = unsafe { std::slice::from_raw_parts_mut(bv.0.add(lo), hi - lo) };
        step(ps, ms, vs, &g[lo..hi]);
    });
}

/// `out = relu?(x @ w + bias?)` with the bias + ReLU epilogue fused into the
/// same parallel row pass (the output rows are still cache-hot when the
/// epilogue touches them). Elementwise epilogues are order-free, so this is
/// bit-identical to matmul-then-bias-then-relu.
#[allow(clippy::too_many_arguments)]
pub fn linear(
    ctx: &KernelCtx,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    let _s = crate::obs::span("kernel.linear");
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if ctx.scalar {
        matmul_ref(x, w, out, m, k, n);
        if let Some(bv) = bias {
            add_bias(out, bv, m, n);
        }
        if relu {
            relu_inplace(out);
        }
        return;
    }
    par_rows(ctx, out, m, n, m * k * n, |lo, hi, out_rows| {
        matmul_rows(x, w, out_rows, lo, hi, k, n);
        if let Some(bv) = bias {
            add_bias(out_rows, bv, hi - lo, n);
        }
        if relu {
            relu_inplace(out_rows);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// Dense random matrix with ~30% exact zeros (exercises zero-skipping).
    fn mat(rng: &mut Pcg64, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| {
                let v = rng.f32();
                if v < 0.3 {
                    0.0
                } else {
                    v * 2.0 - 1.0
                }
            })
            .collect()
    }

    /// Banded matrix `[m x m*band]`: non-zeros only in row `i`'s band, with
    /// some band entries zeroed (padding slots).
    fn banded(rng: &mut Pcg64, m: usize, band: usize) -> Vec<f32> {
        let k = m * band;
        let mut a = vec![0.0f32; m * k];
        for i in 0..m {
            for s in 0..band {
                let v = rng.f32();
                if v > 0.25 {
                    a[i * k + i * band + s] = v;
                }
            }
        }
        a
    }

    /// Shapes chosen odd / non-tile-aligned on purpose, including a k that
    /// crosses the K_TILE boundary.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (7, 13, 5),
        (8, 300, 17), // k crosses K_TILE = 256
        (33, 64, 3),
        (256, 64, 64),
    ];

    const THREADS: &[usize] = &[1, 2, 7];

    #[test]
    fn matmul_matches_ref_bitwise() {
        for &(m, k, n) in SHAPES {
            let mut rng = Pcg64::new(1);
            let a = mat(&mut rng, m * k);
            let b = mat(&mut rng, k * n);
            let mut want = vec![0.0f32; m * n];
            matmul_ref(&a, &b, &mut want, m, k, n);
            for &t in THREADS {
                let ctx = KernelCtx::new(t);
                let mut got = vec![f32::NAN; m * n];
                matmul(&ctx, &a, &b, &mut got, m, k, n);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "matmul ({m},{k},{n}) t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn matmul_at_b_matches_ref_bitwise() {
        for &(r, m, n) in SHAPES {
            let mut rng = Pcg64::new(2);
            let a = mat(&mut rng, r * m);
            let b = mat(&mut rng, r * n);
            for acc in [false, true] {
                let mut want = mat(&mut rng, m * n);
                let base = want.clone();
                matmul_at_b_ref(&a, &b, &mut want, r, m, n, acc);
                for &t in THREADS {
                    let ctx = KernelCtx::new(t);
                    let mut got = base.clone();
                    matmul_at_b(&ctx, &a, &b, &mut got, r, m, n, acc);
                    assert_eq!(
                        bits(&want),
                        bits(&got),
                        "at_b ({r},{m},{n}) acc={acc} t={t} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_a_bt_matches_ref_bitwise() {
        for &(m, k, n) in SHAPES {
            let mut rng = Pcg64::new(3);
            let a = mat(&mut rng, m * k);
            let b = mat(&mut rng, n * k);
            let mut want = vec![0.0f32; m * n];
            matmul_a_bt_ref(&a, &b, &mut want, m, k, n);
            for &t in THREADS {
                let ctx = KernelCtx::new(t);
                let mut got = vec![f32::NAN; m * n];
                matmul_a_bt(&ctx, &a, &b, &mut got, m, k, n);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "a_bt ({m},{k},{n}) t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn banded_kernels_match_dense_ref_bitwise() {
        // (m, band, n) with odd values; k = m * band
        for &(m, band, n) in &[(1usize, 1usize, 1usize), (7, 3, 5), (32, 8, 64), (33, 9, 17)] {
            let k = m * band;
            let mut rng = Pcg64::new(4);
            let a = banded(&mut rng, m, band);
            let b = mat(&mut rng, k * n);
            let mut want = vec![0.0f32; m * n];
            matmul_ref(&a, &b, &mut want, m, k, n);
            for &t in THREADS {
                let ctx = KernelCtx::new(t);
                let mut got = vec![f32::NAN; m * n];
                matmul_banded(&ctx, &a, &b, &mut got, m, k, n, band);
                assert_eq!(bits(&want), bits(&got), "banded ({m},{band},{n}) t={t}");
            }

            // transposed: out is [k x n], reduction over the m rows
            let bt = mat(&mut rng, m * n);
            for acc in [false, true] {
                let mut want_t = mat(&mut rng, k * n);
                let base = want_t.clone();
                matmul_at_b_ref(&a, &bt, &mut want_t, m, k, n, acc);
                for &t in THREADS {
                    let ctx = KernelCtx::new(t);
                    let mut got = base.clone();
                    matmul_at_b_banded(&ctx, &a, &bt, &mut got, m, k, n, band, acc);
                    assert_eq!(
                        bits(&want_t),
                        bits(&got),
                        "banded_at_b ({m},{band},{n}) acc={acc} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn linear_fused_epilogue_matches_unfused() {
        for &(m, k, n) in SHAPES {
            let mut rng = Pcg64::new(5);
            let x = mat(&mut rng, m * k);
            let w = mat(&mut rng, k * n);
            let bias: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            for relu in [false, true] {
                let mut want = vec![0.0f32; m * n];
                matmul_ref(&x, &w, &mut want, m, k, n);
                add_bias(&mut want, &bias, m, n);
                if relu {
                    relu_inplace(&mut want);
                }
                for &t in THREADS {
                    let ctx = KernelCtx::new(t);
                    let mut got = vec![f32::NAN; m * n];
                    linear(&ctx, &x, &w, Some(&bias), &mut got, m, k, n, relu);
                    assert_eq!(bits(&want), bits(&got), "linear ({m},{k},{n}) t={t}");
                }
            }
        }
    }

    #[test]
    fn par_ranges_partitions_exactly_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        for &t in THREADS {
            let ctx = KernelCtx::new(t);
            let rows = 100_003usize; // above MIN_PAR_FLOPS, odd on purpose
            let hits: Vec<AtomicU32> = (0..rows).map(|_| AtomicU32::new(0)).collect();
            par_ranges(&ctx, rows, rows, |lo, hi| {
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "t={t}: some index not covered exactly once"
            );
        }
    }

    #[test]
    fn sgd_update_matches_sequential_bitwise() {
        let n = 50_000usize; // large enough to engage the pool lanes
        let mut rng = Pcg64::new(8);
        let g = mat(&mut rng, n);
        let p0 = mat(&mut rng, n);
        let mut want = p0.clone();
        for (pv, &gv) in want.iter_mut().zip(&g) {
            *pv -= 0.05 * gv;
        }
        for &t in THREADS {
            let ctx = KernelCtx::new(t);
            let mut got = p0.clone();
            sgd_update(&ctx, &mut got, &g, 0.05);
            assert_eq!(bits(&want), bits(&got), "sgd t={t} diverged");
        }
        // scalar flag routes to the sequential reference
        let ctx = KernelCtx::with_pool(Arc::new(ThreadPool::new(4)), true);
        let mut got = p0.clone();
        sgd_update(&ctx, &mut got, &g, 0.05);
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn adam_update_matches_sequential_bitwise() {
        let n = 50_000usize;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let mut rng = Pcg64::new(9);
        let p0 = mat(&mut rng, n);
        // three consecutive steps with fresh grads each, as training does
        let grads: Vec<Vec<f32>> = (0..3).map(|_| mat(&mut rng, n)).collect();
        let run_ref = || {
            let (mut p, mut m, mut v) = (p0.clone(), vec![0.0f32; n], vec![0.0f32; n]);
            for (t, g) in grads.iter().enumerate() {
                let t1 = (t + 1) as f32;
                let (bc1, bc2) = (1.0 - b1.powf(t1), 1.0 - b2.powf(t1));
                for (((pv, &gv), mv), vv) in
                    p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
                {
                    *mv = b1 * *mv + (1.0 - b1) * gv;
                    *vv = b2 * *vv + (1.0 - b2) * gv * gv;
                    let mhat = *mv / bc1;
                    let vhat = *vv / bc2;
                    *pv -= 0.01 * mhat / (vhat.sqrt() + eps);
                }
            }
            (p, m, v)
        };
        let (wp, wm, wv) = run_ref();
        for &t in THREADS {
            let ctx = KernelCtx::new(t);
            let (mut p, mut m, mut v) = (p0.clone(), vec![0.0f32; n], vec![0.0f32; n]);
            for (step, g) in grads.iter().enumerate() {
                let t1 = (step + 1) as f32;
                let (bc1, bc2) = (1.0 - b1.powf(t1), 1.0 - b2.powf(t1));
                adam_update(&ctx, &mut p, &mut m, &mut v, g, 0.01, bc1, bc2, b1, b2, eps);
            }
            assert_eq!(bits(&wp), bits(&p), "adam params t={t} diverged");
            assert_eq!(bits(&wm), bits(&m), "adam m t={t} diverged");
            assert_eq!(bits(&wv), bits(&v), "adam v t={t} diverged");
        }
    }

    #[test]
    fn scalar_flag_routes_to_reference() {
        let mut rng = Pcg64::new(6);
        let (m, k, n) = (9, 11, 4);
        let a = mat(&mut rng, m * k);
        let b = mat(&mut rng, k * n);
        let mut want = vec![0.0f32; m * n];
        matmul_ref(&a, &b, &mut want, m, k, n);
        let ctx = KernelCtx::with_pool(Arc::new(ThreadPool::new(4)), true);
        assert!(ctx.scalar());
        let mut got = vec![f32::NAN; m * n];
        matmul(&ctx, &a, &b, &mut got, m, k, n);
        assert_eq!(bits(&want), bits(&got));
    }

    #[test]
    fn shared_pool_serves_many_kernel_calls() {
        // one pool reused across kernels and iterations (the Runtime usage)
        let pool = Arc::new(ThreadPool::new(3));
        let ctx = KernelCtx::with_pool(pool, false);
        let mut rng = Pcg64::new(7);
        let (m, k, n) = (64, 300, 32);
        let a = mat(&mut rng, m * k);
        let b = mat(&mut rng, k * n);
        let mut want = vec![0.0f32; m * n];
        matmul_ref(&a, &b, &mut want, m, k, n);
        let mut got = vec![0.0f32; m * n];
        for _ in 0..25 {
            matmul(&ctx, &a, &b, &mut got, m, k, n);
            assert_eq!(bits(&want), bits(&got));
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }
}
