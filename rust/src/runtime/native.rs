//! Native reference backend: pure-Rust forward/backward/optimizer for the
//! AOT model zoo, mirroring `python/compile/model.py` op-for-op.
//!
//! Two jobs:
//!
//! 1. **Reference semantics** — the HLO artifacts are opaque; this module is
//!    the readable specification of what they compute (GCN / SAGE / APPNP /
//!    MLP, masked softmax-CE / sigmoid-BCE, SGD / bias-corrected Adam).
//! 2. **Executable fallback** — environments without a real PJRT client
//!    (the vendored `xla` facade) still train, test, and bench through this
//!    backend; [`write_native_manifest`] emits a `manifest.json` with
//!    `"backend": "native"` and the same dataset shape table as
//!    `python/compile/aot.py`, so the whole coordinator stack runs unchanged.
//!
//! GAT is PJRT-only (attention backward is deliberately out of scope for
//! the reference implementation); [`NativeExec::new`] rejects it.
//!
//! All dense/sparse math goes through the tiled kernel layer
//! ([`super::kernels`]): cache-blocked matmuls parallelized over disjoint
//! output-row ranges on a persistent [`super::pool::ThreadPool`], banded
//! kernels for the `A1`/`A2` slot-band aggregation (O(nnz), like the Pallas
//! aggregation kernels on device), and fused bias+ReLU epilogues. The
//! kernels are bit-identical to their scalar references at any thread
//! count — see `runtime/README.md` for the determinism contract — so every
//! result below is independent of the [`KernelCtx`] it ran under.

use std::cell::RefCell;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::sampler::Block;
use crate::util::Json;

use super::kernels::{
    adam_update, add_bias, colsum, linear, matmul, matmul_a_bt, matmul_at_b,
    matmul_at_b_banded, matmul_banded, par_ranges, relu_backward_inplace, relu_inplace,
    sgd_update, KernelCtx, SendMut,
};
use super::{ArtifactMeta, Tensor};

/// Free-list of recycled activation buffers (ROADMAP satellite): the
/// forward pass takes its per-step activations from here instead of
/// allocating, and `loss_and_grads`/`eval_step` return them after the
/// backward pass is done with the caches. Buffers come back with arbitrary
/// contents — every forward output is fully written by its kernel before
/// any read, so no clearing is needed (and none is done).
#[derive(Default)]
struct BufPool {
    free: Vec<Vec<f32>>,
}

impl BufPool {
    fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.resize(len, 0.0);
        v
    }

    fn put(&mut self, v: Vec<f32>) {
        self.free.push(v);
    }
}

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const APPNP_TELEPORT: f32 = 0.1;

/// Architectures the native backend implements.
pub const NATIVE_ARCHS: &[&str] = &["mlp", "gcn", "sage", "appnp"];

/// Ordered `(name, shape)` parameter specs — must match
/// `python/compile/model.py::param_specs` (the manifest records this order
/// and all packing/averaging is positional).
pub fn param_specs(
    arch: &str,
    d: usize,
    h: usize,
    c: usize,
) -> Result<Vec<(&'static str, Vec<usize>)>> {
    Ok(match arch {
        "mlp" | "gcn" | "appnp" => vec![
            ("w1", vec![d, h]),
            ("b1", vec![h]),
            ("w2", vec![h, c]),
            ("b2", vec![c]),
        ],
        "sage" => vec![
            ("ws1", vec![d, h]),
            ("wn1", vec![d, h]),
            ("b1", vec![h]),
            ("ws2", vec![h, c]),
            ("wn2", vec![h, c]),
            ("b2", vec![c]),
        ],
        other => bail!("native backend has no param specs for arch {other:?}"),
    })
}

/// Parameter tensor `i`'s data (positional, manifest order).
fn pd(params: &[Tensor], i: usize) -> &[f32] {
    &params[i].data
}

// ---------------------------------------------------------------------------
// executor
// ---------------------------------------------------------------------------

/// One artifact's native executor: validates shapes once, then runs
/// train/eval steps on host tensors in place.
pub struct NativeExec {
    meta: ArtifactMeta,
    /// recycled per-step activation buffers (see [`BufPool`]); `NativeExec`
    /// lives behind an `Rc` on one thread, so a `RefCell` suffices
    bufs: RefCell<BufPool>,
}

impl NativeExec {
    pub fn new(meta: &ArtifactMeta) -> Result<NativeExec> {
        if !NATIVE_ARCHS.contains(&meta.arch.as_str()) {
            bail!(
                "arch {:?} is not implemented by the native backend (have {:?}); \
                 build PJRT artifacts via `make artifacts` and link the real xla crate",
                meta.arch,
                NATIVE_ARCHS
            );
        }
        if !matches!(meta.loss.as_str(), "softmax_ce" | "sigmoid_bce") {
            bail!("unknown loss {:?}", meta.loss);
        }
        if !matches!(meta.optimizer.as_str(), "sgd" | "adam" | "none") {
            bail!("unknown optimizer {:?}", meta.optimizer);
        }
        let specs = param_specs(&meta.arch, meta.dims.d, meta.dims.h, meta.dims.c)?;
        if specs.len() != meta.params.len()
            || specs
                .iter()
                .zip(&meta.params)
                .any(|((_, s), (_, ms))| s != ms)
        {
            bail!(
                "artifact {} param shapes {:?} do not match native specs {:?}",
                meta.name,
                meta.params,
                specs
            );
        }
        Ok(NativeExec {
            meta: meta.clone(),
            bufs: RefCell::new(BufPool::default()),
        })
    }

    fn check_block(&self, block: &Block) -> Result<()> {
        let dims = &self.meta.dims;
        if block.b != dims.b || block.n1 != dims.n1 || block.n2 != dims.n2 || block.d != dims.d {
            bail!(
                "block dims ({},{},{},d={}) do not match artifact {} ({},{},{},d={})",
                block.b,
                block.n1,
                block.n2,
                block.d,
                self.meta.name,
                dims.b,
                dims.n1,
                dims.n2,
                dims.d
            );
        }
        #[cfg(debug_assertions)]
        {
            // the banded kernels rely on the block-format invariant (see
            // `sampler::BlockBuilder`): row i of A1/A2 holds non-zeros only
            // inside its slot band — verify it in debug builds
            for i in 0..block.b {
                for (j, &v) in block.a1[i * block.n1..(i + 1) * block.n1].iter().enumerate()
                {
                    debug_assert!(
                        v == 0.0 || (j >= i * dims.f1 && j < (i + 1) * dims.f1),
                        "A1 row {i} has an off-band non-zero at col {j}"
                    );
                }
            }
            for i in 0..block.n1 {
                for (j, &v) in block.a2[i * block.n2..(i + 1) * block.n2].iter().enumerate()
                {
                    debug_assert!(
                        v == 0.0 || (j >= i * dims.f2 && j < (i + 1) * dims.f2),
                        "A2 row {i} has an off-band non-zero at col {j}"
                    );
                }
            }
        }
        Ok(())
    }

    /// One optimizer step on `params`/`opt` in place; returns the batch
    /// loss. All matmuls run through `kc`'s kernel engine; the result is
    /// bit-independent of its thread count (see the module docs).
    pub fn train_step(
        &self,
        kc: &KernelCtx,
        params: &mut [Tensor],
        opt: &mut [Tensor],
        block: &Block,
        lr: f32,
    ) -> Result<f32> {
        self.check_block(block)?;
        let (loss, grads) = self.loss_and_grads(kc, params, block)?;
        self.apply_update(kc, params, opt, &grads, lr)?;
        Ok(loss)
    }

    /// Forward only; returns logits `[b * c]`. The logits buffer escapes to
    /// the caller (it is not recycled); the activation caches go back to
    /// the pool.
    pub fn eval_step(&self, kc: &KernelCtx, params: &[Tensor], block: &Block) -> Result<Vec<f32>> {
        self.check_block(block)?;
        let mut pool = self.bufs.borrow_mut();
        let (logits, caches) = self.forward(kc, params, block, &mut pool)?;
        caches.recycle(&mut pool);
        Ok(logits)
    }

    // -- forward -----------------------------------------------------------

    /// Runs the arch forward; returns logits and the activation caches the
    /// backward pass needs (arch-specific layout). `A1`/`A2` products use
    /// the banded aggregation kernels (slot band `f1`/`f2` — see the block
    /// builder); dense layers use the fused-epilogue `linear`. All
    /// activations come from `pool` (arena-recycled across steps) and every
    /// one is fully written by its kernel before any read.
    fn forward(
        &self,
        kc: &KernelCtx,
        params: &[Tensor],
        block: &Block,
        pool: &mut BufPool,
    ) -> Result<(Vec<f32>, Caches)> {
        let d = self.meta.dims.d;
        let h = self.meta.dims.h;
        let c = self.meta.dims.c;
        let (f1, f2) = (self.meta.dims.f1, self.meta.dims.f2);
        let (b, n1, n2) = (block.b, block.n1, block.n2);

        match self.meta.arch.as_str() {
            "mlp" => {
                // h1 = relu(x0 @ w1 + b1); logits = h1 @ w2 + b2
                let mut h1 = pool.take(b * h);
                linear(kc, &block.x0, pd(params, 0), Some(pd(params, 1)), &mut h1, b, d, h, true);
                let mut logits = pool.take(b * c);
                linear(kc, &h1, pd(params, 2), Some(pd(params, 3)), &mut logits, b, h, c, false);
                Ok((logits, Caches::Mlp { h1 }))
            }
            "gcn" => {
                // h1 = relu((A2 @ x2) @ w1 + b1); logits = (A1 @ h1) @ w2 + b2
                let mut agg2 = pool.take(n1 * d);
                matmul_banded(kc, &block.a2, &block.x2, &mut agg2, n1, n2, d, f2);
                let mut h1 = pool.take(n1 * h);
                linear(kc, &agg2, pd(params, 0), Some(pd(params, 1)), &mut h1, n1, d, h, true);
                let mut agg1 = pool.take(b * h);
                matmul_banded(kc, &block.a1, &h1, &mut agg1, b, n1, h, f1);
                let mut logits = pool.take(b * c);
                linear(kc, &agg1, pd(params, 2), Some(pd(params, 3)), &mut logits, b, h, c, false);
                Ok((logits, Caches::Gcn { agg2, h1, agg1 }))
            }
            "sage" => {
                // n1v = A2 @ x2
                let mut n1v = pool.take(n1 * d);
                matmul_banded(kc, &block.a2, &block.x2, &mut n1v, n1, n2, d, f2);
                // h1 = relu(x1 @ ws1 + b1 + n1v @ wn1)
                let mut h1 = pool.take(n1 * h);
                matmul(kc, &block.x1, pd(params, 0), &mut h1, n1, d, h);
                let mut tmp = pool.take(n1 * h);
                matmul(kc, &n1v, pd(params, 1), &mut tmp, n1, d, h);
                for (a, &t) in h1.iter_mut().zip(&tmp) {
                    *a += t;
                }
                pool.put(tmp);
                add_bias(&mut h1, pd(params, 2), n1, h);
                relu_inplace(&mut h1);
                // n0 = A1 @ h1 ; m0 = A1 @ x1
                let mut n0 = pool.take(b * h);
                matmul_banded(kc, &block.a1, &h1, &mut n0, b, n1, h, f1);
                let mut m0 = pool.take(b * d);
                matmul_banded(kc, &block.a1, &block.x1, &mut m0, b, n1, d, f1);
                // h0 = relu(x0 @ ws1 + b1 + m0 @ wn1)
                let mut h0 = pool.take(b * h);
                matmul(kc, &block.x0, pd(params, 0), &mut h0, b, d, h);
                let mut tmp0 = pool.take(b * h);
                matmul(kc, &m0, pd(params, 1), &mut tmp0, b, d, h);
                for (a, &t) in h0.iter_mut().zip(&tmp0) {
                    *a += t;
                }
                pool.put(tmp0);
                add_bias(&mut h0, pd(params, 2), b, h);
                relu_inplace(&mut h0);
                // logits = h0 @ ws2 + b2 + n0 @ wn2
                let mut logits = pool.take(b * c);
                matmul(kc, &h0, pd(params, 3), &mut logits, b, h, c);
                let mut tmpl = pool.take(b * c);
                matmul(kc, &n0, pd(params, 4), &mut tmpl, b, h, c);
                for (a, &t) in logits.iter_mut().zip(&tmpl) {
                    *a += t;
                }
                pool.put(tmpl);
                add_bias(&mut logits, pd(params, 5), b, c);
                Ok((
                    logits,
                    Caches::Sage {
                        n1v,
                        h1,
                        n0,
                        m0,
                        h0,
                    },
                ))
            }
            "appnp" => {
                // mlp(x) at each level; then 2 personalized-PageRank steps
                let beta = APPNP_TELEPORT;
                let mlp = |x: &[f32], rows: usize, pool: &mut BufPool| -> (Vec<f32>, Vec<f32>) {
                    let mut u = pool.take(rows * h);
                    linear(kc, x, pd(params, 0), Some(pd(params, 1)), &mut u, rows, d, h, true);
                    let mut out = pool.take(rows * c);
                    linear(kc, &u, pd(params, 2), Some(pd(params, 3)), &mut out, rows, h, c, false);
                    (out, u)
                };
                let (h2, u2) = mlp(&block.x2, n2, &mut *pool);
                let (h1v, u1) = mlp(&block.x1, n1, &mut *pool);
                let (h0, u0) = mlp(&block.x0, b, &mut *pool);
                // p1 = beta*h1v + (1-beta)*A2@h2
                let mut p1 = pool.take(n1 * c);
                matmul_banded(kc, &block.a2, &h2, &mut p1, n1, n2, c, f2);
                for (o, &hv) in p1.iter_mut().zip(&h1v) {
                    *o = beta * hv + (1.0 - beta) * *o;
                }
                pool.put(h2);
                pool.put(h1v);
                // logits = beta*h0 + (1-beta)*A1@p1
                let mut logits = pool.take(b * c);
                matmul_banded(kc, &block.a1, &p1, &mut logits, b, n1, c, f1);
                for (o, &hv) in logits.iter_mut().zip(&h0) {
                    *o = beta * hv + (1.0 - beta) * *o;
                }
                pool.put(h0);
                pool.put(p1);
                Ok((logits, Caches::Appnp { u2, u1, u0 }))
            }
            other => bail!("native forward: unsupported arch {other:?}"),
        }
    }

    // -- loss + gradients --------------------------------------------------

    fn loss_and_grads(
        &self,
        kc: &KernelCtx,
        params: &[Tensor],
        block: &Block,
    ) -> Result<(f32, Vec<Tensor>)> {
        let mut pool = self.bufs.borrow_mut();
        let (logits, caches) = self.forward(kc, params, block, &mut pool)?;
        let (loss, g) = self.loss_grad(kc, &logits, block, &mut pool)?;
        let grads = self.backward(kc, params, block, &caches, &g)?;
        // everything the step borrowed from the arena goes back for the
        // next step — the per-step activation recycling (ROADMAP satellite)
        pool.put(logits);
        pool.put(g);
        caches.recycle(&mut pool);
        Ok((loss, grads))
    }

    /// Masked mean loss and dL/dlogits `[b,c]`. Rows are independent, so
    /// the per-row max/softmax/gradient work is parallelized over disjoint
    /// row ranges on the kernel pool; the loss reduction stays a sequential
    /// ascending-row fold of per-row terms, so the f32 addition order — and
    /// therefore every bit of the result — matches the sequential loop at
    /// any thread count.
    fn loss_grad(
        &self,
        kc: &KernelCtx,
        logits: &[f32],
        block: &Block,
        pool: &mut BufPool,
    ) -> Result<(f32, Vec<f32>)> {
        let c = self.meta.dims.c;
        let b = block.b;
        let denom = block.mask.iter().sum::<f32>().max(1.0);
        let mut g = pool.take(b * c);
        let mut row_loss = pool.take(b);
        match self.meta.loss.as_str() {
            "softmax_ce" => {
                if block.y_class.len() != b {
                    bail!("softmax_ce needs y_class[{b}], got {}", block.y_class.len());
                }
                // validate before the parallel region (no bail from lanes)
                for i in 0..b {
                    if block.mask[i] != 0.0 && block.y_class[i] as usize >= c {
                        bail!("label {} out of range c={c}", block.y_class[i]);
                    }
                }
                let gp = SendMut(g.as_mut_ptr());
                let lp = SendMut(row_loss.as_mut_ptr());
                par_ranges(kc, b, b * c * 16, |lo, hi| {
                    // SAFETY: disjoint in-bounds row ranges per lane;
                    // par_ranges blocks until every lane returns.
                    let gs = unsafe {
                        std::slice::from_raw_parts_mut(gp.0.add(lo * c), (hi - lo) * c)
                    };
                    let ls =
                        unsafe { std::slice::from_raw_parts_mut(lp.0.add(lo), hi - lo) };
                    for i in lo..hi {
                        let grow = &mut gs[(i - lo) * c..(i - lo + 1) * c];
                        let mask = block.mask[i];
                        if mask == 0.0 {
                            grow.fill(0.0);
                            ls[i - lo] = 0.0;
                            continue;
                        }
                        let row = &logits[i * c..(i + 1) * c];
                        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                        let sum: f32 = row.iter().map(|&z| (z - max).exp()).sum();
                        let y = block.y_class[i] as usize;
                        ls[i - lo] = mask * (sum.ln() - (row[y] - max));
                        let scale = mask / denom;
                        for (j, (gv, &z)) in grow.iter_mut().zip(row).enumerate() {
                            let p = (z - max).exp() / sum;
                            *gv = scale * (p - if j == y { 1.0 } else { 0.0 });
                        }
                    }
                });
            }
            "sigmoid_bce" => {
                if block.y_multi.len() != b * c {
                    bail!(
                        "sigmoid_bce needs y_multi[{}], got {}",
                        b * c,
                        block.y_multi.len()
                    );
                }
                let gp = SendMut(g.as_mut_ptr());
                let lp = SendMut(row_loss.as_mut_ptr());
                par_ranges(kc, b, b * c * 16, |lo, hi| {
                    // SAFETY: see the softmax branch.
                    let gs = unsafe {
                        std::slice::from_raw_parts_mut(gp.0.add(lo * c), (hi - lo) * c)
                    };
                    let ls =
                        unsafe { std::slice::from_raw_parts_mut(lp.0.add(lo), hi - lo) };
                    for i in lo..hi {
                        let grow = &mut gs[(i - lo) * c..(i - lo + 1) * c];
                        let mask = block.mask[i];
                        if mask == 0.0 {
                            grow.fill(0.0);
                            ls[i - lo] = 0.0;
                            continue;
                        }
                        let row = &logits[i * c..(i + 1) * c];
                        let yrow = &block.y_multi[i * c..(i + 1) * c];
                        let mut row_bce = 0.0f32;
                        for ((gv, &z), &y) in grow.iter_mut().zip(row).zip(yrow) {
                            row_bce += z.max(0.0) - z * y + (-z.abs()).exp().ln_1p();
                            let sig = 1.0 / (1.0 + (-z).exp());
                            *gv = mask / denom * (sig - y) / c as f32;
                        }
                        ls[i - lo] = mask * row_bce / c as f32;
                    }
                });
            }
            other => bail!("unknown loss {other:?}"),
        }
        // the sequential reduction, in the exact order the old single-loop
        // version accumulated (ascending rows, masked rows skipped)
        let mut loss = 0.0f32;
        for i in 0..b {
            if block.mask[i] != 0.0 {
                loss += row_loss[i];
            }
        }
        pool.put(row_loss);
        Ok((loss / denom, g))
    }

    /// Backprop `g = dL/dlogits` to parameter gradients (same order/shapes
    /// as `params`). The `A1ᵀ`/`A2ᵀ` products use the banded-transpose
    /// kernel (one contribution per output row).
    fn backward(
        &self,
        kc: &KernelCtx,
        params: &[Tensor],
        block: &Block,
        caches: &Caches,
        g: &[f32],
    ) -> Result<Vec<Tensor>> {
        let d = self.meta.dims.d;
        let h = self.meta.dims.h;
        let c = self.meta.dims.c;
        let (f1, f2) = (self.meta.dims.f1, self.meta.dims.f2);
        let (b, n1, n2) = (block.b, block.n1, block.n2);
        let mut grads: Vec<Tensor> = params.iter().map(|t| Tensor::zeros(&t.shape)).collect();

        match (self.meta.arch.as_str(), caches) {
            ("mlp", Caches::Mlp { h1 }) => {
                // [w1, b1, w2, b2]
                matmul_at_b(kc, h1, g, &mut grads[2].data, b, h, c, false);
                colsum(g, &mut grads[3].data, b, c, false);
                let mut dh1 = vec![0.0; b * h];
                matmul_a_bt(kc, g, pd(params, 2), &mut dh1, b, c, h);
                relu_backward_inplace(&mut dh1, h1);
                matmul_at_b(kc, &block.x0, &dh1, &mut grads[0].data, b, d, h, false);
                colsum(&dh1, &mut grads[1].data, b, h, false);
            }
            ("gcn", Caches::Gcn { agg2, h1, agg1 }) => {
                // [w1, b1, w2, b2]
                matmul_at_b(kc, agg1, g, &mut grads[2].data, b, h, c, false);
                colsum(g, &mut grads[3].data, b, c, false);
                let mut dagg1 = vec![0.0; b * h];
                matmul_a_bt(kc, g, pd(params, 2), &mut dagg1, b, c, h);
                let mut dh1 = vec![0.0; n1 * h];
                matmul_at_b_banded(kc, &block.a1, &dagg1, &mut dh1, b, n1, h, f1, false);
                relu_backward_inplace(&mut dh1, h1);
                matmul_at_b(kc, agg2, &dh1, &mut grads[0].data, n1, d, h, false);
                colsum(&dh1, &mut grads[1].data, n1, h, false);
            }
            (
                "sage",
                Caches::Sage {
                    n1v,
                    h1,
                    n0,
                    m0,
                    h0,
                },
            ) => {
                // [ws1, wn1, b1, ws2, wn2, b2]
                matmul_at_b(kc, h0, g, &mut grads[3].data, b, h, c, false);
                matmul_at_b(kc, n0, g, &mut grads[4].data, b, h, c, false);
                colsum(g, &mut grads[5].data, b, c, false);
                // self path at level 0
                let mut dh0 = vec![0.0; b * h];
                matmul_a_bt(kc, g, pd(params, 3), &mut dh0, b, c, h);
                relu_backward_inplace(&mut dh0, h0);
                // neighbor path through the level-1 embeddings
                let mut dn0 = vec![0.0; b * h];
                matmul_a_bt(kc, g, pd(params, 4), &mut dn0, b, c, h);
                let mut dh1 = vec![0.0; n1 * h];
                matmul_at_b_banded(kc, &block.a1, &dn0, &mut dh1, b, n1, h, f1, false);
                relu_backward_inplace(&mut dh1, h1);
                // shared layer-1 weights accumulate from both levels
                matmul_at_b(kc, &block.x0, &dh0, &mut grads[0].data, b, d, h, false);
                matmul_at_b(kc, &block.x1, &dh1, &mut grads[0].data, n1, d, h, true);
                matmul_at_b(kc, m0, &dh0, &mut grads[1].data, b, d, h, false);
                matmul_at_b(kc, n1v, &dh1, &mut grads[1].data, n1, d, h, true);
                colsum(&dh0, &mut grads[2].data, b, h, false);
                colsum(&dh1, &mut grads[2].data, n1, h, true);
            }
            ("appnp", Caches::Appnp { u2, u1, u0 }) => {
                // [w1, b1, w2, b2]; dL/dmlp-out at each level, then the
                // shared MLP accumulates over the three calls.
                let beta = APPNP_TELEPORT;
                let mut dp1 = vec![0.0; n1 * c];
                matmul_at_b_banded(kc, &block.a1, g, &mut dp1, b, n1, c, f1, false);
                for v in dp1.iter_mut() {
                    *v *= 1.0 - beta;
                }
                let mut dh2 = vec![0.0; n2 * c];
                matmul_at_b_banded(kc, &block.a2, &dp1, &mut dh2, n1, n2, c, f2, false);
                for v in dh2.iter_mut() {
                    *v *= 1.0 - beta;
                }
                let dh1: Vec<f32> = dp1.iter().map(|&v| beta * v).collect();
                let dh0: Vec<f32> = g.iter().map(|&v| beta * v).collect();
                let mut first = true;
                for (x, u, dh, rows) in [
                    (&block.x2, u2, &dh2, n2),
                    (&block.x1, u1, &dh1, n1),
                    (&block.x0, u0, &dh0, b),
                ] {
                    matmul_at_b(kc, u, dh, &mut grads[2].data, rows, h, c, !first);
                    colsum(dh, &mut grads[3].data, rows, c, !first);
                    let mut du = vec![0.0; rows * h];
                    matmul_a_bt(kc, dh, pd(params, 2), &mut du, rows, c, h);
                    relu_backward_inplace(&mut du, u);
                    matmul_at_b(kc, x, &du, &mut grads[0].data, rows, d, h, !first);
                    colsum(&du, &mut grads[1].data, rows, h, !first);
                    first = false;
                }
            }
            (arch, _) => bail!("native backward: cache/arch mismatch for {arch:?}"),
        }
        Ok(grads)
    }

    // -- optimizer ---------------------------------------------------------

    /// One optimizer step, elementwise over every tensor — runs through the
    /// parallel update kernels (`kernels::sgd_update` / `adam_update`),
    /// which are bit-identical to the sequential loops at any thread count
    /// (element-independent updates over disjoint lane ranges).
    fn apply_update(
        &self,
        kc: &KernelCtx,
        params: &mut [Tensor],
        opt: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
    ) -> Result<()> {
        match self.meta.optimizer.as_str() {
            "sgd" => {
                for (pt, gt) in params.iter_mut().zip(grads) {
                    sgd_update(kc, &mut pt.data, &gt.data, lr);
                }
            }
            "adam" => {
                let n = params.len();
                if opt.len() != 2 * n + 1 {
                    bail!("adam expects {} opt tensors, got {}", 2 * n + 1, opt.len());
                }
                let (ms, rest) = opt.split_at_mut(n);
                let (vs, tt) = rest.split_at_mut(n);
                let t1 = tt[0].data[0] + 1.0;
                tt[0].data[0] = t1;
                let bc1 = 1.0 - ADAM_B1.powf(t1);
                let bc2 = 1.0 - ADAM_B2.powf(t1);
                for (((pt, gt), mt), vt) in
                    params.iter_mut().zip(grads).zip(ms).zip(vs)
                {
                    adam_update(
                        kc,
                        &mut pt.data,
                        &mut mt.data,
                        &mut vt.data,
                        &gt.data,
                        lr,
                        bc1,
                        bc2,
                        ADAM_B1,
                        ADAM_B2,
                        ADAM_EPS,
                    );
                }
            }
            other => bail!("apply_update on optimizer {other:?}"),
        }
        Ok(())
    }
}

/// Per-arch activation caches threaded from forward to backward.
enum Caches {
    Mlp {
        h1: Vec<f32>,
    },
    Gcn {
        agg2: Vec<f32>,
        h1: Vec<f32>,
        agg1: Vec<f32>,
    },
    Sage {
        n1v: Vec<f32>,
        h1: Vec<f32>,
        n0: Vec<f32>,
        m0: Vec<f32>,
        h0: Vec<f32>,
    },
    Appnp {
        u2: Vec<f32>,
        u1: Vec<f32>,
        u0: Vec<f32>,
    },
}

impl Caches {
    /// Return every cached activation to the arena once the backward pass
    /// is done with it.
    fn recycle(self, pool: &mut BufPool) {
        match self {
            Caches::Mlp { h1 } => pool.put(h1),
            Caches::Gcn { agg2, h1, agg1 } => {
                pool.put(agg2);
                pool.put(h1);
                pool.put(agg1);
            }
            Caches::Sage {
                n1v,
                h1,
                n0,
                m0,
                h0,
            } => {
                pool.put(n1v);
                pool.put(h1);
                pool.put(n0);
                pool.put(m0);
                pool.put(h0);
            }
            Caches::Appnp { u2, u1, u0 } => {
                pool.put(u2);
                pool.put(u1);
                pool.put(u0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// native manifest (the `make artifacts` substitute)
// ---------------------------------------------------------------------------

struct ShapeCfg {
    name: &'static str,
    d: usize,
    c: usize,
    h: usize,
    b: usize,
    f1: usize,
    f2: usize,
    loss: &'static str,
    archs: &'static [&'static str],
}

/// Dataset shape table — `python/compile/aot.py::DATASETS` minus GAT
/// (PJRT-only).
const SHAPES: &[ShapeCfg] = &[
    ShapeCfg { name: "tiny", d: 16, c: 4, h: 16, b: 8, f1: 4, f2: 4, loss: "softmax_ce", archs: &["gcn", "sage", "mlp"] },
    ShapeCfg { name: "tiny-hetero", d: 16, c: 4, h: 16, b: 8, f1: 4, f2: 4, loss: "softmax_ce", archs: &["gcn", "sage"] },
    ShapeCfg { name: "flickr-s", d: 64, c: 7, h: 64, b: 32, f1: 8, f2: 8, loss: "softmax_ce", archs: &["gcn", "sage", "appnp"] },
    ShapeCfg { name: "proteins-s", d: 16, c: 16, h: 64, b: 32, f1: 8, f2: 8, loss: "sigmoid_bce", archs: &["gcn", "sage", "appnp"] },
    ShapeCfg { name: "arxiv-s", d: 32, c: 16, h: 64, b: 32, f1: 8, f2: 8, loss: "softmax_ce", archs: &["gcn", "sage", "appnp"] },
    ShapeCfg { name: "reddit-s", d: 64, c: 16, h: 64, b: 32, f1: 8, f2: 8, loss: "softmax_ce", archs: &["gcn", "sage", "appnp"] },
    ShapeCfg { name: "yelp-s", d: 32, c: 12, h: 64, b: 32, f1: 8, f2: 8, loss: "sigmoid_bce", archs: &["gcn", "mlp"] },
    ShapeCfg { name: "products-s", d: 32, c: 12, h: 64, b: 32, f1: 8, f2: 8, loss: "softmax_ce", archs: &["sage", "gcn"] },
];

fn artifact_json(
    name: &str,
    kind: &str,
    arch: &str,
    optimizer: &str,
    cfg: &ShapeCfg,
    n_opt: usize,
) -> Result<Json> {
    let n1 = cfg.b * cfg.f1;
    let n2 = cfg.b * cfg.f1 * cfg.f2;
    let params = param_specs(arch, cfg.d, cfg.h, cfg.c)?
        .into_iter()
        .map(|(pname, shape)| {
            Json::obj(vec![
                ("name", Json::str(pname)),
                (
                    "shape",
                    Json::arr(shape.into_iter().map(|s| Json::num(s as f64)).collect()),
                ),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("name", Json::str(name)),
        ("file", Json::str("")),
        ("kind", Json::str(kind)),
        ("arch", Json::str(arch)),
        ("optimizer", Json::str(optimizer)),
        ("loss", Json::str(cfg.loss)),
        ("dataset", Json::str(cfg.name)),
        (
            "dims",
            Json::obj(vec![
                ("b", Json::num(cfg.b as f64)),
                ("n1", Json::num(n1 as f64)),
                ("n2", Json::num(n2 as f64)),
                ("d", Json::num(cfg.d as f64)),
                ("h", Json::num(cfg.h as f64)),
                ("c", Json::num(cfg.c as f64)),
                ("f1", Json::num(cfg.f1 as f64)),
                ("f2", Json::num(cfg.f2 as f64)),
            ]),
        ),
        ("params", Json::arr(params)),
        ("n_opt", Json::num(n_opt as f64)),
    ]))
}

/// Write a `"backend": "native"` manifest covering the full shape table
/// into `dir/manifest.json` (atomic rename, safe under parallel tests).
pub fn write_native_manifest(dir: impl AsRef<Path>) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut artifacts = Vec::new();
    for cfg in SHAPES {
        for &arch in cfg.archs {
            let n_params = param_specs(arch, cfg.d, cfg.h, cfg.c)?.len();
            for opt in ["adam", "sgd"] {
                let name = format!("{arch}_{opt}_{}", cfg.name);
                let n_opt = if opt == "adam" { 2 * n_params + 1 } else { 0 };
                artifacts.push(artifact_json(&name, "train", arch, opt, cfg, n_opt)?);
            }
            let name = format!("{arch}_eval_{}", cfg.name);
            artifacts.push(artifact_json(&name, "eval", arch, "none", cfg, 0)?);
        }
    }
    let manifest = Json::obj(vec![
        ("format", Json::num(1.0)),
        ("backend", Json::str("native")),
        ("artifacts", Json::arr(artifacts)),
    ]);
    // unique tmp per call (pid + counter): parallel test threads may write
    // concurrently, and rename() is atomic, so last writer wins cleanly
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = dir.join(format!("manifest.json.tmp.{}.{seq}", std::process::id()));
    std::fs::write(&tmp, manifest.to_string_pretty())?;
    std::fs::rename(&tmp, dir.join("manifest.json"))
        .map_err(|e| anyhow!("installing native manifest: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::runtime::{ModelState, Runtime};
    use crate::sampler::BlockBuilder;
    use crate::util::Pcg64;

    fn tiny_exec(arch: &str, optimizer: &str) -> (NativeExec, ArtifactMeta) {
        let specs = param_specs(arch, 16, 16, 4).unwrap();
        let n_params = specs.len();
        let meta = ArtifactMeta {
            name: format!("{arch}_{optimizer}_tiny"),
            file: String::new(),
            kind: "train".into(),
            arch: arch.into(),
            optimizer: optimizer.into(),
            loss: "softmax_ce".into(),
            dataset: "tiny".into(),
            dims: super::super::Dims {
                b: 8,
                n1: 32,
                n2: 128,
                d: 16,
                h: 16,
                c: 4,
                f1: 4,
                f2: 4,
            },
            params: specs
                .into_iter()
                .map(|(n, s)| (n.to_string(), s))
                .collect(),
            n_opt: if optimizer == "adam" { 2 * n_params + 1 } else { 0 },
        };
        (NativeExec::new(&meta).unwrap(), meta)
    }

    fn tiny_block(meta: &ArtifactMeta, seed: u64) -> (crate::graph::Dataset, crate::sampler::Block) {
        let ds = generators::by_name("tiny", 0).unwrap();
        let bb = BlockBuilder::new(
            meta.dims.b,
            meta.dims.f1,
            meta.dims.f2,
            meta.dims.d,
            meta.dims.c,
            false,
        );
        let mut rng = Pcg64::new(seed);
        let targets: Vec<u32> = ds.splits.train[..meta.dims.b].to_vec();
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        (ds, blk)
    }

    #[test]
    fn gradcheck_all_archs_and_losses() {
        // central finite differences on a handful of coordinates per tensor;
        // kernel-thread count is irrelevant to the results (bit-identical
        // contract), so run the check under a 2-lane pool
        let kc = KernelCtx::new(2);
        for arch in ["mlp", "gcn", "sage", "appnp"] {
            let (exec, meta) = tiny_exec(arch, "sgd");
            let (_ds, blk) = tiny_block(&meta, 3);
            let mut rng = Pcg64::new(5);
            let state = ModelState::init(&meta, &mut rng);
            let (_, grads) = exec.loss_and_grads(&kc, &state.params, &blk).unwrap();
            let eps = 1e-2f32;
            for (ti, t) in state.params.iter().enumerate() {
                let probes = [0usize, t.data.len() / 2, t.data.len() - 1];
                for &j in probes.iter() {
                    let mut plus = state.params.clone();
                    plus[ti].data[j] += eps;
                    let (lp, _) = exec.loss_and_grads(&kc, &plus, &blk).unwrap();
                    let mut minus = state.params.clone();
                    minus[ti].data[j] -= eps;
                    let (lm, _) = exec.loss_and_grads(&kc, &minus, &blk).unwrap();
                    let fd = (lp - lm) / (2.0 * eps);
                    let an = grads[ti].data[j];
                    assert!(
                        (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                        "{arch} tensor {ti} coord {j}: fd={fd} analytic={an}"
                    );
                }
            }
        }
    }

    #[test]
    fn sgd_training_reduces_loss_on_fixed_batch() {
        let kc = KernelCtx::new(1);
        for arch in ["mlp", "gcn", "sage", "appnp"] {
            let (exec, meta) = tiny_exec(arch, "sgd");
            let (_ds, blk) = tiny_block(&meta, 7);
            let mut rng = Pcg64::new(11);
            let mut state = ModelState::init(&meta, &mut rng);
            let first = exec
                .train_step(&kc, &mut state.params, &mut state.opt, &blk, 0.1)
                .unwrap();
            let mut last = first;
            for _ in 0..30 {
                last = exec
                    .train_step(&kc, &mut state.params, &mut state.opt, &blk, 0.1)
                    .unwrap();
            }
            assert!(last < first * 0.8, "{arch}: loss {first} -> {last}");
        }
    }

    #[test]
    fn adam_counter_and_convergence() {
        let kc = KernelCtx::new(1);
        let (exec, meta) = tiny_exec("gcn", "adam");
        let (_ds, blk) = tiny_block(&meta, 9);
        let mut rng = Pcg64::new(13);
        let mut state = ModelState::init(&meta, &mut rng);
        assert_eq!(state.opt.len(), 2 * state.params.len() + 1);
        let first = exec
            .train_step(&kc, &mut state.params, &mut state.opt, &blk, 0.01)
            .unwrap();
        for i in 1..=20 {
            exec.train_step(&kc, &mut state.params, &mut state.opt, &blk, 0.01)
                .unwrap();
            assert_eq!(state.opt.last().unwrap().data[0], (i + 1) as f32);
        }
        let last = exec
            .train_step(&kc, &mut state.params, &mut state.opt, &blk, 0.01)
            .unwrap();
        assert!(last < first, "adam: {first} -> {last}");
    }

    #[test]
    fn lr_zero_is_noop_on_params() {
        let kc = KernelCtx::new(1);
        let (exec, meta) = tiny_exec("sage", "sgd");
        let (_ds, blk) = tiny_block(&meta, 15);
        let mut rng = Pcg64::new(17);
        let mut state = ModelState::init(&meta, &mut rng);
        let before = state.params.clone();
        exec.train_step(&kc, &mut state.params, &mut state.opt, &blk, 0.0)
            .unwrap();
        for (a, b) in state.params.iter().zip(&before) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn full_step_is_bit_identical_across_thread_counts_and_scalar() {
        // the whole-executor determinism contract: scalar reference vs the
        // tiled kernels at 1/2/7 lanes, over several consecutive steps
        for arch in ["mlp", "gcn", "sage", "appnp"] {
            let (exec, meta) = tiny_exec(arch, "sgd");
            let (_ds, blk) = tiny_block(&meta, 21);
            let mut rng = Pcg64::new(23);
            let init = ModelState::init(&meta, &mut rng);

            let run = |kc: &KernelCtx| -> (Vec<f32>, ModelState) {
                let mut state = init.clone();
                let mut losses = Vec::new();
                for _ in 0..3 {
                    losses.push(
                        exec.train_step(kc, &mut state.params, &mut state.opt, &blk, 0.05)
                            .unwrap(),
                    );
                }
                (losses, state)
            };
            let scalar_kc = KernelCtx::with_pool(
                std::sync::Arc::new(crate::runtime::pool::ThreadPool::new(1)),
                true,
            );
            let (want_losses, want_state) = run(&scalar_kc);
            for threads in [1usize, 2, 7] {
                let (losses, state) = run(&KernelCtx::new(threads));
                assert_eq!(
                    want_losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
                    losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
                    "{arch} t={threads}: loss stream diverged from scalar"
                );
                for (a, b) in want_state.params.iter().zip(&state.params) {
                    assert_eq!(a.data, b.data, "{arch} t={threads}: params diverged");
                }
            }
        }
    }

    #[test]
    fn gat_is_rejected() {
        let meta = ArtifactMeta {
            name: "gat_sgd_tiny".into(),
            file: String::new(),
            kind: "train".into(),
            arch: "gat".into(),
            optimizer: "sgd".into(),
            loss: "softmax_ce".into(),
            dataset: "tiny".into(),
            dims: super::super::Dims {
                b: 8,
                n1: 32,
                n2: 128,
                d: 16,
                h: 16,
                c: 4,
                f1: 4,
                f2: 4,
            },
            params: vec![],
            n_opt: 0,
        };
        assert!(NativeExec::new(&meta).is_err());
    }

    #[test]
    fn native_manifest_loads() {
        let dir = std::env::temp_dir().join(format!("llcg-native-{}", std::process::id()));
        write_native_manifest(&dir).unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert!(rt.meta("gcn_adam_tiny").is_ok());
        assert!(rt.meta("sage_eval_reddit-s").is_ok());
        assert!(rt.meta("gat_adam_reddit-s").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
