//! Runtime: executes train/eval steps for the coordinator's hot path behind
//! one of two backends, selected by the artifact manifest:
//!
//! - **`pjrt`** — loads the AOT-compiled HLO-text artifacts (built once by
//!   `make artifacts`) and executes them on a PJRT client (`xla` crate).
//!   Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!   Executables compile lazily on first use and are cached per process.
//! - **`native`** — the pure-Rust reference implementation of the same model
//!   zoo ([`native`]), used when PJRT or the artifacts are unavailable
//!   (`manifest.json` carries `"backend": "native"`; see
//!   [`Runtime::load_or_native`]).
//!
//! Python is never involved at run time.
//!
//! ## Device-resident execution model
//!
//! The legacy path ([`Runtime::train_step`]) serializes the full model +
//! optimizer state through host literals on **every** step — upload, execute,
//! download. That is wasteful at Algorithm 2's cadence, where a worker runs
//! `K·ρ^r` consecutive local steps between synchronizations.
//!
//! [`DeviceState`] instead keeps parameters + optimizer state resident on the
//! execution device across steps:
//!
//! ```text
//! round r:   upload once          Runtime::upload(name, state)
//!            K local steps        Runtime::train_step_device(&mut dev, ..)
//!                                   — only the block + lr cross to the
//!                                     device; only the scalar loss returns
//!            download once        Runtime::download_into(&dev, state)
//! ```
//!
//! Host `Tensor`s are materialized **only at round boundaries** — exactly
//! where Algorithm 2 needs them (parameter averaging, server correction
//! hand-off, eval). Under the PJRT backend the step outputs stay device-side
//! as `PjRtBuffer`s and are fed straight back in (`execute_b`, untupled
//! outputs); under the native backend the state lives in host tensors
//! mutated in place, so the "upload"/"download" are each a single copy and
//! steps are zero-copy. Both backends produce bit-identical results between
//! the resident and the legacy literal path — see the parity tests.
//!
//! The queued-loss variant ([`Runtime::train_step_device_queued`] +
//! [`DeviceState::take_losses`]) removes even the per-step scalar-loss sync:
//! losses accumulate device-side and are drained in one batch per round.
//!
//! ## Pinned block-input staging
//!
//! The sampled block tensors (`a1/a2/x0/x1/x2`, labels, mask) are the one
//! input that must cross to the device every step. Their shapes are static
//! per artifact, so [`DeviceState`] carries pinned, shape-stable staging
//! ([`BlockLits`]) that is overwritten in place from the `BlockArena`'s
//! block each step instead of re-allocated: under PJRT the host literals
//! are reused across steps (only the device copy remains per-step; buffer
//! donation needs the real `xla` crate — see ROADMAP); under the native
//! backend host memory *is* device memory, so the arena block is consumed
//! in place with zero staging.
//!
//! ## Kernel engine
//!
//! The native backend executes through the tiled, multi-threaded kernel
//! layer ([`kernels`]) over a persistent [`pool::ThreadPool`] owned by this
//! runtime. [`Runtime::set_kernel_threads`] sizes the pool (`0` = all host
//! cores; the cluster engine sizes per-worker pools as `cores / P`), and
//! every kernel is bit-identical to its scalar reference at any thread
//! count — see `runtime/README.md` for the determinism contract.

pub mod kernels;
pub mod native;
pub mod pool;

pub use kernels::KernelCtx;
pub use pool::ThreadPool;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::sampler::Block;
use crate::util::{Json, Pcg64};

/// A dense f32 tensor (shape + row-major data).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; len],
        }
    }

    /// Glorot/Xavier-uniform init for weight matrices, zeros for vectors.
    pub fn glorot(shape: &[usize], rng: &mut Pcg64) -> Tensor {
        if shape.len() < 2 {
            return Tensor::zeros(shape);
        }
        let (fan_in, fan_out) = (shape[0] as f64, shape[1] as f64);
        let limit = (6.0 / (fan_in + fan_out)).sqrt() as f32;
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..len)
                .map(|_| (rng.f32() * 2.0 - 1.0) * limit)
                .collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> u64 {
        self.numel() as u64 * 4
    }

    /// Copy `src` tensors into `dst` element-wise, reusing `dst`'s buffers
    /// when shapes line up (falls back to cloning on first use / reshape).
    pub fn copy_all(dst: &mut Vec<Tensor>, src: &[Tensor]) {
        if dst.len() != src.len() || dst.iter().zip(src).any(|(a, b)| a.shape != b.shape) {
            *dst = src.to_vec();
            return;
        }
        for (d, s) in dst.iter_mut().zip(src) {
            d.data.copy_from_slice(&s.data);
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        // single-copy path (perf pass 2): vec1().reshape() copies twice
        f32_literal(&self.data, &self.shape)
    }
}

/// Build an f32 literal from a slice in one copy (vs `vec1` + `reshape`).
fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

fn f32_bytes(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn i32_bytes(data: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

/// One fresh literal per block input, in artifact input order
/// `[a1, a2, x0, x1, x2]` plus — when `with_labels` (train artifacts) —
/// `[y, mask]`. The unpinned staging path, kept as the baseline for the
/// pinned-parity tests and the `bench kernels` staged-vs-pinned rows.
pub fn fresh_block_literals(
    multilabel: bool,
    with_labels: bool,
    block: &Block,
) -> Result<Vec<xla::Literal>> {
    let (b, n1, n2, d, c) = (block.b, block.n1, block.n2, block.d, block.c);
    let mut lits = vec![
        f32_literal(&block.a1, &[b, n1])?,
        f32_literal(&block.a2, &[n1, n2])?,
        f32_literal(&block.x0, &[b, d])?,
        f32_literal(&block.x1, &[n1, d])?,
        f32_literal(&block.x2, &[n2, d])?,
    ];
    if with_labels {
        lits.push(if multilabel {
            f32_literal(&block.y_multi, &[b, c])?
        } else {
            i32_literal(&block.y_class, &[b])?
        });
        lits.push(f32_literal(&block.mask, &[b])?);
    }
    Ok(lits)
}

/// Pinned, shape-stable host staging for one block's input literals (order
/// `[a1, a2, x0, x1, x2(, y, mask)]`). The first [`stage`] allocates; every
/// later stage with an unchanged shape overwrites the literal bytes in
/// place — zero allocation on the step hot path. Eval artifacts take no
/// labels, so they stage with `with_labels: false` and skip the y/mask
/// copies entirely.
///
/// [`stage`]: BlockLits::stage
#[derive(Default)]
pub struct BlockLits {
    lits: Vec<xla::Literal>,
    /// (b, n1, n2, d, c, multilabel, with_labels) of the staged shape
    shape: Option<(usize, usize, usize, usize, usize, bool, bool)>,
}

impl BlockLits {
    pub fn new() -> BlockLits {
        BlockLits::default()
    }

    /// Stage `block` into the pinned literals; allocation-free when the
    /// shape is unchanged. Returns the literals in artifact input order.
    pub fn stage(
        &mut self,
        multilabel: bool,
        with_labels: bool,
        block: &Block,
    ) -> Result<&[xla::Literal]> {
        let shape = (
            block.b,
            block.n1,
            block.n2,
            block.d,
            block.c,
            multilabel,
            with_labels,
        );
        if self.shape != Some(shape) {
            self.lits = fresh_block_literals(multilabel, with_labels, block)?;
            self.shape = Some(shape);
            return Ok(&self.lits);
        }
        self.lits[0].copy_from_untyped_data(f32_bytes(&block.a1))?;
        self.lits[1].copy_from_untyped_data(f32_bytes(&block.a2))?;
        self.lits[2].copy_from_untyped_data(f32_bytes(&block.x0))?;
        self.lits[3].copy_from_untyped_data(f32_bytes(&block.x1))?;
        self.lits[4].copy_from_untyped_data(f32_bytes(&block.x2))?;
        if with_labels {
            if multilabel {
                self.lits[5].copy_from_untyped_data(f32_bytes(&block.y_multi))?;
            } else {
                self.lits[5].copy_from_untyped_data(i32_bytes(&block.y_class))?;
            }
            self.lits[6].copy_from_untyped_data(f32_bytes(&block.mask))?;
        }
        Ok(&self.lits)
    }
}

/// Static dims of one artifact's block format.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub b: usize,
    pub n1: usize,
    pub n2: usize,
    pub d: usize,
    pub h: usize,
    pub c: usize,
    pub f1: usize,
    pub f2: usize,
}

/// Manifest entry for one compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String, // "train" | "eval"
    pub arch: String,
    pub optimizer: String, // "adam" | "sgd" | "none"
    pub loss: String,      // "softmax_ce" | "sigmoid_bce"
    pub dataset: String,
    pub dims: Dims,
    /// ordered (name, shape)
    pub params: Vec<(String, Vec<usize>)>,
    pub n_opt: usize,
}

impl ArtifactMeta {
    pub fn multilabel(&self) -> bool {
        self.loss == "sigmoid_bce"
    }

    pub fn param_bytes(&self) -> u64 {
        self.params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>() as u64 * 4)
            .sum()
    }

    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let dims = j.req("dims");
        let gd = |k: &str| -> Result<usize> {
            dims.req(k)
                .as_usize()
                .ok_or_else(|| anyhow!("dims.{k} not a number"))
        };
        let params = j
            .req("params")
            .as_array()
            .ok_or_else(|| anyhow!("params not an array"))?
            .iter()
            .map(|p| {
                let name = p.req("name").as_str().unwrap_or_default().to_string();
                let shape: Vec<usize> = p
                    .req("shape")
                    .as_array()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect();
                (name, shape)
            })
            .collect();
        Ok(ArtifactMeta {
            name: j.req("name").as_str().unwrap_or_default().to_string(),
            file: j.req("file").as_str().unwrap_or_default().to_string(),
            kind: j.req("kind").as_str().unwrap_or_default().to_string(),
            arch: j.req("arch").as_str().unwrap_or_default().to_string(),
            optimizer: j.req("optimizer").as_str().unwrap_or_default().to_string(),
            loss: j.req("loss").as_str().unwrap_or_default().to_string(),
            dataset: j.req("dataset").as_str().unwrap_or_default().to_string(),
            dims: Dims {
                b: gd("b")?,
                n1: gd("n1")?,
                n2: gd("n2")?,
                d: gd("d")?,
                h: gd("h")?,
                c: gd("c")?,
                f1: gd("f1")?,
                f2: gd("f2")?,
            },
            params,
            n_opt: j.req("n_opt").as_usize().unwrap_or(0),
        })
    }
}

/// Model parameters + optimizer state, in manifest order.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<Tensor>,
    /// adam: [m.., v.., t]; sgd: empty
    pub opt: Vec<Tensor>,
}

impl ModelState {
    /// Fresh state for a train artifact (Glorot weights, zero opt state).
    pub fn init(meta: &ArtifactMeta, rng: &mut Pcg64) -> ModelState {
        let params: Vec<Tensor> = meta
            .params
            .iter()
            .map(|(_, s)| Tensor::glorot(s, rng))
            .collect();
        let opt = if meta.optimizer == "adam" {
            let mut opt: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
            opt.extend(params.iter().map(|p| Tensor::zeros(&p.shape)));
            opt.push(Tensor::zeros(&[])); // t
            opt
        } else {
            Vec::new()
        };
        ModelState { params, opt }
    }

    pub fn param_bytes(&self) -> u64 {
        self.params.iter().map(|p| p.size_bytes()).sum()
    }

    /// Elementwise average of many states' *parameters* (Alg. 2 line 12).
    /// Optimizer state is not averaged (it stays local, like FedAvg+Adam).
    pub fn average_params(states: &[&ModelState]) -> Vec<Tensor> {
        let mut out = Vec::new();
        Self::average_params_into(&mut out, states);
        out
    }

    /// [`average_params`] into reusable accumulators: zero-allocated buffers
    /// (no clone-then-zero), one accumulation pass, one final scale pass.
    /// `out` is (re)allocated only on first use or shape change.
    ///
    /// [`average_params`]: ModelState::average_params
    pub fn average_params_into(out: &mut Vec<Tensor>, states: &[&ModelState]) {
        assert!(!states.is_empty());
        let proto = &states[0].params;
        if out.len() != proto.len() || out.iter().zip(proto).any(|(a, p)| a.shape != p.shape) {
            *out = proto.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        } else {
            for t in out.iter_mut() {
                t.data.fill(0.0);
            }
        }
        for s in states {
            for (acc, p) in out.iter_mut().zip(&s.params) {
                debug_assert_eq!(acc.shape, p.shape);
                for (a, &x) in acc.data.iter_mut().zip(&p.data) {
                    *a += x;
                }
            }
        }
        let scale = 1.0 / states.len() as f32;
        for t in out.iter_mut() {
            for a in t.data.iter_mut() {
                *a *= scale;
            }
        }
    }

    pub fn set_params(&mut self, params: Vec<Tensor>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }

    /// Overwrite parameters in place from `params` (no allocation).
    pub fn copy_params_from(&mut self, params: &[Tensor]) {
        assert_eq!(params.len(), self.params.len());
        for (dst, src) in self.params.iter_mut().zip(params) {
            debug_assert_eq!(dst.shape, src.shape);
            dst.data.copy_from_slice(&src.data);
        }
    }
}

/// Model + optimizer state resident on the execution device between local
/// steps. Created by [`Runtime::upload`], advanced by
/// [`Runtime::train_step_device`] (immediate loss) or
/// [`Runtime::train_step_device_queued`] (loss stays device-side),
/// materialized back to host tensors at round boundaries by
/// [`Runtime::download_into`] / [`DeviceState::take_losses`].
pub struct DeviceState {
    name: String,
    n_params: usize,
    n_opt: usize,
    steps: u64,
    slots: DeviceSlots,
    /// per-step losses not yet synced to the host (queued path)
    pending_losses: Vec<PendingLoss>,
    /// pinned block-input staging (PJRT path; the native backend consumes
    /// the arena block in place — host memory is device memory there)
    block_lits: BlockLits,
    /// pinned rank-0 learning-rate literal, refreshed in place per step
    lr_lit: Option<xla::Literal>,
}

enum DeviceSlots {
    /// Native backend: host tensors mutated in place (params then opt).
    Native(Vec<Tensor>),
    /// PJRT backend: device buffers, replaced by each step's outputs.
    Pjrt(Vec<xla::PjRtBuffer>),
}

/// Per-row eval reductions returned by [`Runtime::eval_scores_device`]:
/// `O(b)` values in place of the full `b × c` logits download.
pub struct EvalScores {
    /// per-row argmax over classes (first-max tie-break, as
    /// `metrics::argmax`)
    pub pred: Vec<u32>,
    /// per-row bitmask of strictly-positive logits (the multilabel
    /// prediction rule); class `j` is bit `j`, valid for `c <= 64`
    pub pos_bits: Vec<u64>,
    /// per-row loss against the block's labels, matching
    /// `metrics::mean_loss`'s per-row f64 formula exactly
    pub loss: Vec<f64>,
}

/// A step's loss before the host has synced it.
enum PendingLoss {
    /// native backend: already host-side, zero cost
    Host(f32),
    /// PJRT backend: still a device buffer; synced in [`DeviceState::take_losses`]
    Pjrt(xla::PjRtBuffer),
}

impl DeviceState {
    /// Artifact this state was uploaded for.
    pub fn artifact(&self) -> &str {
        &self.name
    }

    /// Local steps executed since upload.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Losses queued by [`Runtime::train_step_device_queued`], in step order.
    /// This is the *one* per-round loss readback: under PJRT each queued
    /// step left its scalar loss on the device, and this drains them all in
    /// a single host sync pass at the round boundary.
    pub fn take_losses(&mut self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.pending_losses.len());
        for l in self.pending_losses.drain(..) {
            out.push(match l {
                PendingLoss::Host(v) => v,
                PendingLoss::Pjrt(buf) => buf.to_literal_sync()?.to_vec::<f32>()?[0],
            });
        }
        Ok(out)
    }
}

/// The runtime: manifest + backend + lazily prepared executables.
pub struct Runtime {
    backend: Backend,
    dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    /// native-backend kernel engine: thread count, scalar override, pool
    kernel: RefCell<KernelCfg>,
    /// executions performed (profiling)
    pub exec_count: RefCell<u64>,
}

/// Kernel-engine configuration; the pool is built lazily on first use and
/// rebuilt when the requested thread count changes.
struct KernelCfg {
    /// requested lanes (0 = auto: all host cores)
    threads: usize,
    /// force the scalar reference kernels (bench baseline / parity tests)
    scalar: bool,
    pool: Option<std::sync::Arc<ThreadPool>>,
}

enum Backend {
    Pjrt {
        client: xla::PjRtClient,
        execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    },
    Native {
        execs: RefCell<HashMap<String, Rc<native::NativeExec>>>,
    },
}

impl Runtime {
    /// Load `dir/manifest.json`; artifacts compile lazily on first use.
    /// The manifest's `"backend"` key ("pjrt" default) selects the engine.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {manifest_path:?} — run `make artifacts` first to AOT-compile the models"
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut metas = HashMap::new();
        for a in j
            .req("artifacts")
            .as_array()
            .ok_or_else(|| anyhow!("manifest.artifacts missing"))?
        {
            let meta = ArtifactMeta::from_json(a)?;
            metas.insert(meta.name.clone(), meta);
        }
        let backend = match j.get("backend").and_then(|b| b.as_str()).unwrap_or("pjrt") {
            "native" => Backend::Native {
                execs: RefCell::new(HashMap::new()),
            },
            "pjrt" => Backend::Pjrt {
                client: xla::PjRtClient::cpu()
                    .with_context(|| "creating PJRT client for a pjrt-backend manifest")?,
                execs: RefCell::new(HashMap::new()),
            },
            other => bail!("unknown manifest backend {other:?}"),
        };
        Ok(Runtime {
            backend,
            dir,
            metas,
            kernel: RefCell::new(KernelCfg {
                threads: 0,
                scalar: false,
                pool: None,
            }),
            exec_count: RefCell::new(0),
        })
    }

    /// Size the native kernel pool: `threads` parallel lanes (0 = auto: all
    /// host cores). Takes effect on the next kernel call; a live pool of a
    /// different size is dropped (joining its workers) and rebuilt. The
    /// cluster engine calls this per worker runtime so that
    /// `P workers × T lanes` never oversubscribes the host.
    pub fn set_kernel_threads(&self, threads: usize) {
        let mut k = self.kernel.borrow_mut();
        if k.threads != threads {
            k.threads = threads;
            k.pool = None;
        }
    }

    /// Resolved kernel lane count (after 0 → host cores).
    pub fn kernel_threads(&self) -> usize {
        let k = self.kernel.borrow();
        if k.threads == 0 {
            pool::host_threads()
        } else {
            k.threads
        }
    }

    /// Force the scalar reference kernels (benchmark baseline and parity
    /// tests; results are bit-identical either way).
    pub fn set_kernel_scalar(&self, scalar: bool) {
        self.kernel.borrow_mut().scalar = scalar;
    }

    /// Kernel context for one executor call (shared pool, built lazily).
    fn kernel_ctx(&self) -> KernelCtx {
        let mut k = self.kernel.borrow_mut();
        if k.pool.is_none() {
            let t = if k.threads == 0 {
                pool::host_threads()
            } else {
                k.threads
            };
            k.pool = Some(std::sync::Arc::new(ThreadPool::new(t)));
        }
        KernelCtx::with_pool(k.pool.as_ref().expect("just built").clone(), k.scalar)
    }

    /// Load `preferred` if its manifest exists *and* is executable in this
    /// build; otherwise (re)generate the native-backend manifest under
    /// `target/native-artifacts` and load that. Returns the runtime and the
    /// artifact dir actually used.
    pub fn load_or_native(preferred: impl AsRef<Path>) -> Result<(Runtime, String)> {
        let p = preferred.as_ref();
        if p.join("manifest.json").exists() {
            match Runtime::load(p) {
                Ok(rt) => return Ok((rt, p.display().to_string())),
                Err(e) => eprintln!(
                    "note: artifacts at {p:?} not usable here ({e:#}); \
                     falling back to the native backend"
                ),
            }
        }
        let dir = Path::new("target/native-artifacts");
        // reuse an existing manifest when it is current (parallel test
        // threads all land here; regenerating every call is wasted I/O)
        if dir.join("manifest.json").exists() {
            if let Ok(rt) = Runtime::load(dir) {
                if rt.backend_name() == "native" && rt.meta("gcn_adam_tiny").is_ok() {
                    return Ok((rt, dir.display().to_string()));
                }
            }
        }
        native::write_native_manifest(dir)?;
        let rt = Runtime::load(dir)?;
        Ok((rt, dir.display().to_string()))
    }

    /// Directory the manifest was loaded from — lets another thread build
    /// its own `Runtime` over the same artifacts (the cluster engine gives
    /// every worker thread a private runtime; `Runtime` itself is not
    /// `Send`).
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Backend actually in use ("pjrt" | "native").
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Native { .. } => "native",
        }
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                self.metas.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.metas.keys().map(|s| s.as_str()).collect()
    }

    /// Conventional artifact names.
    pub fn train_name(arch: &str, optimizer: &str, dataset: &str) -> String {
        format!("{arch}_{optimizer}_{dataset}")
    }

    pub fn eval_name(arch: &str, dataset: &str) -> String {
        format!("{arch}_eval_{dataset}")
    }

    fn exec_pjrt(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let Backend::Pjrt { client, execs } = &self.backend else {
            bail!("{name}: runtime backend is not pjrt");
        };
        if let Some(e) = execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.meta(name)?;
        if meta.file.is_empty() {
            bail!("artifact {name} carries no HLO file (native manifest?)");
        }
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(client.compile(&comp)?);
        execs.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn exec_native(&self, name: &str) -> Result<Rc<native::NativeExec>> {
        let Backend::Native { execs } = &self.backend else {
            bail!("{name}: runtime backend is not native");
        };
        if let Some(e) = execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.meta(name)?;
        let exe = Rc::new(native::NativeExec::new(meta)?);
        execs.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile / pre-validate an artifact (so timing loops exclude it).
    pub fn warmup(&self, name: &str) -> Result<()> {
        match &self.backend {
            Backend::Pjrt { .. } => self.exec_pjrt(name).map(|_| ()),
            Backend::Native { .. } => self.exec_native(name).map(|_| ()),
        }
    }

    fn check_block_dims(&self, meta: &ArtifactMeta, block: &Block) -> Result<()> {
        let dims = &meta.dims;
        if block.b != dims.b
            || block.n1 != dims.n1
            || block.n2 != dims.n2
            || block.d != dims.d
            || block.c != dims.c
        {
            bail!(
                "block dims ({},{},{},d={},c={}) do not match artifact {} \
                 ({},{},{},d={},c={})",
                block.b,
                block.n1,
                block.n2,
                block.d,
                block.c,
                meta.name,
                dims.b,
                dims.n1,
                dims.n2,
                dims.d,
                dims.c
            );
        }
        Ok(())
    }

    /// Validated fresh-literal staging for one artifact call — thin wrapper
    /// keeping [`fresh_block_literals`] the single source of the
    /// ABI-load-bearing input order.
    fn staged_block_literals(
        &self,
        meta: &ArtifactMeta,
        block: &Block,
        with_labels: bool,
    ) -> Result<Vec<xla::Literal>> {
        self.check_block_dims(meta, block)?;
        fresh_block_literals(meta.multilabel(), with_labels, block)
    }

    // -- legacy host-literal path ------------------------------------------

    /// Run one train step through the host-literal path: the full model +
    /// optimizer state round-trips host↔device on every call. Retained as
    /// the reference/baseline; the round loop uses the device-resident path
    /// below. Mutates `state` in place; returns the batch loss.
    pub fn train_step(
        &self,
        name: &str,
        state: &mut ModelState,
        block: &Block,
        lr: f32,
    ) -> Result<f32> {
        match &self.backend {
            Backend::Pjrt { .. } => self.train_step_pjrt_literal(name, state, block, lr),
            Backend::Native { .. } => {
                let meta = self.meta(name)?;
                if meta.kind != "train" {
                    bail!("{name} is not a train artifact");
                }
                let exe = self.exec_native(name)?;
                // faithful literal-path cost model: state is copied in and
                // out around the step, as the PJRT literal path does
                let mut staged: Vec<Tensor> = state
                    .params
                    .iter()
                    .chain(state.opt.iter())
                    .cloned()
                    .collect();
                let n = state.params.len();
                *self.exec_count.borrow_mut() += 1;
                let (p, o) = staged.split_at_mut(n);
                let loss = exe.train_step(&self.kernel_ctx(), p, o, block, lr)?;
                for (dst, src) in state
                    .params
                    .iter_mut()
                    .chain(state.opt.iter_mut())
                    .zip(&staged)
                {
                    dst.data.copy_from_slice(&src.data);
                }
                Ok(loss)
            }
        }
    }

    fn train_step_pjrt_literal(
        &self,
        name: &str,
        state: &mut ModelState,
        block: &Block,
        lr: f32,
    ) -> Result<f32> {
        let meta = self.meta(name)?.clone();
        if meta.kind != "train" {
            bail!("{name} is not a train artifact");
        }
        let exe = self.exec_pjrt(name)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(
            state.params.len() + state.opt.len() + 8,
        );
        for p in &state.params {
            inputs.push(p.to_literal()?);
        }
        for o in &state.opt {
            inputs.push(o.to_literal()?);
        }
        inputs.extend(self.staged_block_literals(&meta, block, true)?);
        inputs.push(xla::Literal::scalar(lr));

        *self.exec_count.borrow_mut() += 1;
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let expect = 1 + state.params.len() + state.opt.len();
        if outs.len() != expect {
            bail!("{name}: expected {expect} outputs, got {}", outs.len());
        }
        let mut iter = outs.into_iter();
        let loss = iter.next().unwrap().to_vec::<f32>()?[0];
        for p in state.params.iter_mut() {
            p.data = iter.next().unwrap().to_vec::<f32>()?;
        }
        for o in state.opt.iter_mut() {
            o.data = iter.next().unwrap().to_vec::<f32>()?;
        }
        Ok(loss)
    }

    /// Run one eval step through the host-literal path; returns logits
    /// `[b * c]`.
    pub fn eval_step(&self, name: &str, params: &[Tensor], block: &Block) -> Result<Vec<f32>> {
        let meta = self.meta(name)?.clone();
        if meta.kind != "eval" {
            bail!("{name} is not an eval artifact");
        }
        match &self.backend {
            Backend::Pjrt { .. } => {
                let exe = self.exec_pjrt(name)?;
                let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + 5);
                for p in params {
                    inputs.push(p.to_literal()?);
                }
                inputs.extend(self.staged_block_literals(&meta, block, false)?);
                *self.exec_count.borrow_mut() += 1;
                let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
                let logits = result.to_tuple1()?;
                Ok(logits.to_vec::<f32>()?)
            }
            Backend::Native { .. } => {
                let exe = self.exec_native(name)?;
                // literal-path cost model: params staged per call
                let staged: Vec<Tensor> = params.to_vec();
                *self.exec_count.borrow_mut() += 1;
                exe.eval_step(&self.kernel_ctx(), &staged, block)
            }
        }
    }

    // -- device-resident path ----------------------------------------------

    /// Upload model + optimizer state to the device once; subsequent
    /// [`train_step_device`] calls run without host round-trips.
    ///
    /// [`train_step_device`]: Runtime::train_step_device
    pub fn upload(&self, name: &str, state: &ModelState) -> Result<DeviceState> {
        self.upload_tensors(name, &state.params, &state.opt)
    }

    /// Upload parameters only (eval artifacts carry no optimizer state).
    pub fn upload_params(&self, name: &str, params: &[Tensor]) -> Result<DeviceState> {
        self.upload_tensors(name, params, &[])
    }

    fn upload_tensors(
        &self,
        name: &str,
        params: &[Tensor],
        opt: &[Tensor],
    ) -> Result<DeviceState> {
        let meta = self.meta(name)?;
        if params.len() != meta.params.len() {
            bail!(
                "{name}: uploading {} params, artifact has {}",
                params.len(),
                meta.params.len()
            );
        }
        if meta.kind == "train" && opt.len() != meta.n_opt {
            bail!(
                "{name}: uploading {} opt tensors, artifact has {}",
                opt.len(),
                meta.n_opt
            );
        }
        let slots = match &self.backend {
            Backend::Native { .. } => {
                // the upload copy: state becomes device-owned until download
                DeviceSlots::Native(params.iter().chain(opt.iter()).cloned().collect())
            }
            Backend::Pjrt { client, .. } => {
                let mut bufs = Vec::with_capacity(params.len() + opt.len());
                for t in params.iter().chain(opt.iter()) {
                    bufs.push(client.buffer_from_host_literal(&t.to_literal()?)?);
                }
                DeviceSlots::Pjrt(bufs)
            }
        };
        Ok(DeviceState {
            name: name.to_string(),
            n_params: params.len(),
            n_opt: opt.len(),
            steps: 0,
            slots,
            pending_losses: Vec::new(),
            block_lits: BlockLits::new(),
            lr_lit: None,
        })
    }

    /// One train step on device-resident state: only the block + learning
    /// rate cross to the device; only the scalar loss syncs back.
    pub fn train_step_device(
        &self,
        dev: &mut DeviceState,
        block: &Block,
        lr: f32,
    ) -> Result<f32> {
        match self.train_step_device_inner(dev, block, lr)? {
            PendingLoss::Host(v) => Ok(v),
            PendingLoss::Pjrt(buf) => Ok(buf.to_literal_sync()?.to_vec::<f32>()?[0]),
        }
    }

    /// One train step on device-resident state with *no* per-step host sync:
    /// the scalar loss is queued device-side and drained in one batch at the
    /// round boundary by [`DeviceState::take_losses`]. This removes the last
    /// per-step host round-trip of the Alg. 2 inner loop.
    pub fn train_step_device_queued(
        &self,
        dev: &mut DeviceState,
        block: &Block,
        lr: f32,
    ) -> Result<()> {
        let loss = self.train_step_device_inner(dev, block, lr)?;
        dev.pending_losses.push(loss);
        Ok(())
    }

    fn train_step_device_inner(
        &self,
        dev: &mut DeviceState,
        block: &Block,
        lr: f32,
    ) -> Result<PendingLoss> {
        let meta = self.meta(&dev.name)?.clone();
        if meta.kind != "train" {
            bail!("{} is not a train artifact", dev.name);
        }
        *self.exec_count.borrow_mut() += 1;
        let loss = match (&self.backend, &mut dev.slots) {
            (Backend::Native { .. }, DeviceSlots::Native(tensors)) => {
                let exe = self.exec_native(&dev.name)?;
                let (p, o) = tensors.split_at_mut(dev.n_params);
                PendingLoss::Host(exe.train_step(&self.kernel_ctx(), p, o, block, lr)?)
            }
            (Backend::Pjrt { client, .. }, DeviceSlots::Pjrt(bufs)) => {
                let exe = self.exec_pjrt(&dev.name)?;
                self.check_block_dims(&meta, block)?;
                // pinned staging: the 7 block literals + the lr scalar live
                // in the DeviceState and are overwritten in place each step
                let lits = dev.block_lits.stage(meta.multilabel(), true, block)?;
                if let Some(l) = dev.lr_lit.as_mut() {
                    l.copy_from_untyped_data(&lr.to_le_bytes())?;
                } else {
                    dev.lr_lit = Some(xla::Literal::scalar(lr));
                }
                let lr_lit = dev.lr_lit.as_ref().expect("just staged");
                let mut staged: Vec<xla::PjRtBuffer> = Vec::with_capacity(8);
                for lit in lits.iter() {
                    staged.push(client.buffer_from_host_literal(lit)?);
                }
                staged.push(client.buffer_from_host_literal(lr_lit)?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    Vec::with_capacity(bufs.len() + staged.len());
                args.extend(bufs.iter());
                args.extend(staged.iter());
                let mut replicas = exe.execute_b(&args)?;
                if replicas.is_empty() {
                    bail!("{}: no replica outputs", dev.name);
                }
                let outs = replicas.swap_remove(0);
                let expect = 1 + dev.n_params + dev.n_opt;
                if outs.len() != expect {
                    bail!(
                        "{}: expected {expect} untupled outputs, got {} \
                         (compile with untuple_result)",
                        dev.name,
                        outs.len()
                    );
                }
                let mut it = outs.into_iter();
                let loss_buf = it.next().expect("length checked");
                *bufs = it.collect();
                // the loss stays a device buffer; callers decide whether to
                // sync it now (train_step_device) or queue it (…_queued)
                PendingLoss::Pjrt(loss_buf)
            }
            _ => bail!(
                "{}: DeviceState backend does not match this runtime",
                dev.name
            ),
        };
        dev.steps += 1;
        Ok(loss)
    }

    /// Eval on device-resident parameters (uploaded once per eval sweep).
    /// Block inputs go through the state's pinned staging (`&mut` for the
    /// in-place overwrite; the compute itself does not mutate).
    pub fn eval_step_device(&self, dev: &mut DeviceState, block: &Block) -> Result<Vec<f32>> {
        let meta = self.meta(&dev.name)?.clone();
        if meta.kind != "eval" {
            bail!("{} is not an eval artifact", dev.name);
        }
        *self.exec_count.borrow_mut() += 1;
        match (&self.backend, &dev.slots) {
            (Backend::Native { .. }, DeviceSlots::Native(tensors)) => {
                let exe = self.exec_native(&dev.name)?;
                exe.eval_step(&self.kernel_ctx(), &tensors[..dev.n_params], block)
            }
            (Backend::Pjrt { client, .. }, DeviceSlots::Pjrt(bufs)) => {
                let exe = self.exec_pjrt(&dev.name)?;
                self.check_block_dims(&meta, block)?;
                // eval artifacts take only the 5 block tensors (no labels)
                let lits = dev.block_lits.stage(meta.multilabel(), false, block)?;
                let mut staged: Vec<xla::PjRtBuffer> = Vec::with_capacity(lits.len());
                for lit in lits {
                    staged.push(client.buffer_from_host_literal(lit)?);
                }
                let mut args: Vec<&xla::PjRtBuffer> =
                    Vec::with_capacity(bufs.len() + staged.len());
                args.extend(bufs.iter());
                args.extend(staged.iter());
                let mut replicas = exe.execute_b(&args)?;
                if replicas.is_empty() || replicas[0].is_empty() {
                    bail!("{}: no outputs", dev.name);
                }
                let out = replicas.swap_remove(0).swap_remove(0);
                Ok(out.to_literal_sync()?.to_tuple1()?.to_vec::<f32>()?)
            }
            _ => bail!(
                "{}: DeviceState backend does not match this runtime",
                dev.name
            ),
        }
    }

    /// Device-side eval reductions: run the eval forward and reduce the
    /// logits to per-row quantities *before* they are handed to the caller —
    /// the argmax prediction, the positive-logit bitmask (multilabel
    /// prediction), and the per-row loss against the block's labels. The
    /// caller receives `O(b)` values instead of the `b × c` logits tensor;
    /// every reduction matches its `metrics::*` counterpart bit-for-bit
    /// (first-max argmax, `mean_loss`'s f64 row formula).
    ///
    /// Under the native backend the reduction runs where the logits already
    /// live; under PJRT it currently costs the same single logits download
    /// as [`eval_step_device`] — fusing the reduction into the eval
    /// artifact is the remaining step (see ROADMAP).
    pub fn eval_scores_device(&self, dev: &mut DeviceState, block: &Block) -> Result<EvalScores> {
        let meta = self.meta(&dev.name)?.clone();
        let c = meta.dims.c;
        if c > 64 {
            // pos_bits is a u64 bitmask; silently truncating predictions
            // would corrupt any metric built from them
            bail!(
                "eval_scores_device supports c <= 64 (got {c}); use \
                 eval_step_device + metrics on the full logits instead"
            );
        }
        let logits = self.eval_step_device(dev, block)?;
        let b = block.b;
        let multilabel = meta.multilabel();
        if multilabel && block.y_multi.len() != b * c {
            bail!("eval_scores_device needs y_multi[{}]", b * c);
        }
        if !multilabel && block.y_class.len() != b {
            bail!("eval_scores_device needs y_class[{b}]");
        }
        let mut scores = EvalScores {
            pred: Vec::with_capacity(b),
            pos_bits: Vec::with_capacity(b),
            loss: Vec::with_capacity(b),
        };
        for i in 0..b {
            let row = &logits[i * c..(i + 1) * c];
            // the exact reductions the logits path applies — shared helpers,
            // so the bit-parity with `score`/`mean_loss` is structural
            scores.pred.push(crate::metrics::argmax(row) as u32);
            let mut bits = 0u64;
            for (j, &x) in row.iter().enumerate() {
                if x > 0.0 {
                    bits |= 1 << j;
                }
            }
            scores.pos_bits.push(bits);
            scores.loss.push(if multilabel {
                crate::metrics::row_bce_loss(row, &block.y_multi[i * c..(i + 1) * c])
            } else {
                let target = block.y_class[i] as usize;
                if target >= c {
                    bail!("label {target} out of range c={c}");
                }
                crate::metrics::row_ce_loss(row, target)
            });
        }
        Ok(scores)
    }

    /// Materialize device-resident state back into host tensors — the
    /// round-boundary download (averaging / correction / eval hand-off).
    pub fn download_into(&self, dev: &DeviceState, state: &mut ModelState) -> Result<()> {
        if state.params.len() != dev.n_params || state.opt.len() != dev.n_opt {
            bail!(
                "{}: download into state with {}+{} tensors, device has {}+{}",
                dev.name,
                state.params.len(),
                state.opt.len(),
                dev.n_params,
                dev.n_opt
            );
        }
        match &dev.slots {
            DeviceSlots::Native(tensors) => {
                for (dst, src) in state
                    .params
                    .iter_mut()
                    .chain(state.opt.iter_mut())
                    .zip(tensors)
                {
                    dst.data.copy_from_slice(&src.data);
                }
                Ok(())
            }
            DeviceSlots::Pjrt(bufs) => {
                for (dst, buf) in state
                    .params
                    .iter_mut()
                    .chain(state.opt.iter_mut())
                    .zip(bufs)
                {
                    dst.data = buf.to_literal_sync()?.to_vec::<f32>()?;
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_glorot_bounds() {
        let mut rng = Pcg64::new(1);
        let t = Tensor::glorot(&[64, 32], &mut rng);
        let limit = (6.0f32 / 96.0).sqrt();
        assert!(t.data.iter().all(|&x| x.abs() <= limit));
        assert!(t.data.iter().any(|&x| x.abs() > limit * 0.5));
        let b = Tensor::glorot(&[32], &mut rng);
        assert!(b.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn average_params() {
        let a = ModelState {
            params: vec![Tensor {
                shape: vec![2],
                data: vec![1.0, 3.0],
            }],
            opt: vec![],
        };
        let b = ModelState {
            params: vec![Tensor {
                shape: vec![2],
                data: vec![3.0, 5.0],
            }],
            opt: vec![],
        };
        let avg = ModelState::average_params(&[&a, &b]);
        assert_eq!(avg[0].data, vec![2.0, 4.0]);
    }

    #[test]
    fn average_params_into_reuses_accumulators() {
        let mk = |x: f32| ModelState {
            params: vec![Tensor {
                shape: vec![3],
                data: vec![x, 2.0 * x, -x],
            }],
            opt: vec![],
        };
        let (a, b, c) = (mk(1.0), mk(2.0), mk(6.0));
        let mut acc = Vec::new();
        ModelState::average_params_into(&mut acc, &[&a, &b, &c]);
        assert_eq!(acc[0].data, vec![3.0, 6.0, -3.0]);
        let ptr = acc[0].data.as_ptr();
        // second round must reuse the same buffer and fully overwrite it
        ModelState::average_params_into(&mut acc, &[&a, &b]);
        assert_eq!(acc[0].data, vec![1.5, 3.0, -1.5]);
        assert_eq!(acc[0].data.as_ptr(), ptr, "accumulator was reallocated");
    }

    #[test]
    fn copy_helpers_overwrite_in_place() {
        let src = vec![Tensor {
            shape: vec![2],
            data: vec![5.0, 6.0],
        }];
        let mut state = ModelState {
            params: vec![Tensor {
                shape: vec![2],
                data: vec![0.0, 0.0],
            }],
            opt: vec![],
        };
        let ptr = state.params[0].data.as_ptr();
        state.copy_params_from(&src);
        assert_eq!(state.params[0].data, vec![5.0, 6.0]);
        assert_eq!(state.params[0].data.as_ptr(), ptr);

        let mut dst: Vec<Tensor> = Vec::new();
        Tensor::copy_all(&mut dst, &src); // first call clones
        let p2 = dst[0].data.as_ptr();
        Tensor::copy_all(&mut dst, &src); // second reuses
        assert_eq!(dst[0].data.as_ptr(), p2);
        assert_eq!(dst[0].data, vec![5.0, 6.0]);
    }

    #[test]
    fn pinned_block_staging_matches_fresh_literals() {
        use crate::graph::generators;
        use crate::sampler::BlockBuilder;

        let ds = generators::by_name("tiny", 0).unwrap();
        let bb = BlockBuilder::new(8, 4, 4, ds.d, ds.c(), false);
        let mut rng = Pcg64::new(5);
        let mut pinned = BlockLits::new();
        // several consecutive blocks through one pinned staging (first call
        // allocates, later calls overwrite in place) vs fresh literals
        for round in 0..4 {
            let targets: Vec<u32> = (round * 8..round * 8 + 8).collect();
            let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
            let fresh = fresh_block_literals(false, true, &blk).unwrap();
            let staged = pinned.stage(false, true, &blk).unwrap();
            assert_eq!(fresh.len(), staged.len());
            for (i, (f, s)) in fresh.iter().zip(staged.iter()).enumerate() {
                assert_eq!(f.shape(), s.shape(), "round {round} input {i}: shape");
                assert_eq!(
                    f.element_type(),
                    s.element_type(),
                    "round {round} input {i}: dtype"
                );
                if i == 5 {
                    // the label literal is i32 for multiclass blocks
                    assert_eq!(
                        f.to_vec::<i32>().unwrap(),
                        s.to_vec::<i32>().unwrap(),
                        "round {round}: labels"
                    );
                } else {
                    let fv = f.to_vec::<f32>().unwrap();
                    let sv = s.to_vec::<f32>().unwrap();
                    assert_eq!(
                        fv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        sv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        "round {round} input {i}: payload"
                    );
                }
            }
        }
    }

    #[test]
    fn pinned_block_staging_reshapes_on_shape_change() {
        use crate::graph::generators;
        use crate::sampler::BlockBuilder;

        let ds = generators::by_name("tiny", 1).unwrap();
        let mut rng = Pcg64::new(6);
        let mut pinned = BlockLits::new();
        let bb1 = BlockBuilder::new(8, 4, 4, ds.d, ds.c(), false);
        let blk1 = bb1.build(&[0, 1, 2], &ds.graph, &ds, &mut rng);
        assert_eq!(pinned.stage(false, true, &blk1).unwrap()[0].shape(), &[8, 32]);
        let bb2 = BlockBuilder::new(4, 3, 2, ds.d, ds.c(), false);
        let blk2 = bb2.build(&[5, 6], &ds.graph, &ds, &mut rng);
        // a different block shape must re-allocate, not corrupt
        assert_eq!(pinned.stage(false, true, &blk2).unwrap()[0].shape(), &[4, 12]);
        let fresh = fresh_block_literals(false, true, &blk2).unwrap();
        let staged = pinned.stage(false, true, &blk2).unwrap();
        assert_eq!(
            fresh[1].to_vec::<f32>().unwrap(),
            staged[1].to_vec::<f32>().unwrap()
        );
    }

    #[test]
    fn manifest_meta_parsing() {
        let j = Json::parse(
            r#"{"name":"gcn_sgd_tiny","file":"x.hlo.txt","kind":"train",
                "arch":"gcn","optimizer":"sgd","loss":"softmax_ce","dataset":"tiny",
                "dims":{"b":8,"n1":32,"n2":128,"d":16,"h":16,"c":4,"f1":4,"f2":4},
                "params":[{"name":"w1","shape":[16,16]},{"name":"b1","shape":[16]}],
                "n_opt":0}"#,
        )
        .unwrap();
        let m = ArtifactMeta::from_json(&j).unwrap();
        assert_eq!(m.dims.n2, 128);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.param_bytes(), (16 * 16 + 16) * 4);
        assert!(!m.multilabel());
    }
}
