//! PJRT runtime: loads the AOT-compiled HLO-text artifacts (built once by
//! `make artifacts`) and executes train/eval steps from the coordinator's
//! hot path. Python is never involved at run time.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily on first use and cached per process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::sampler::Block;
use crate::util::{Json, Pcg64};

/// A dense f32 tensor (shape + row-major data).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; len],
        }
    }

    /// Glorot/Xavier-uniform init for weight matrices, zeros for vectors.
    pub fn glorot(shape: &[usize], rng: &mut Pcg64) -> Tensor {
        if shape.len() < 2 {
            return Tensor::zeros(shape);
        }
        let (fan_in, fan_out) = (shape[0] as f64, shape[1] as f64);
        let limit = (6.0 / (fan_in + fan_out)).sqrt() as f32;
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..len)
                .map(|_| (rng.f32() * 2.0 - 1.0) * limit)
                .collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> u64 {
        self.numel() as u64 * 4
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        // single-copy path (perf pass 2): vec1().reshape() copies twice
        f32_literal(&self.data, &self.shape)
    }
}

/// Build an f32 literal from a slice in one copy (vs `vec1` + `reshape`).
fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Static dims of one artifact's block format.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub b: usize,
    pub n1: usize,
    pub n2: usize,
    pub d: usize,
    pub h: usize,
    pub c: usize,
    pub f1: usize,
    pub f2: usize,
}

/// Manifest entry for one compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String, // "train" | "eval"
    pub arch: String,
    pub optimizer: String, // "adam" | "sgd" | "none"
    pub loss: String,      // "softmax_ce" | "sigmoid_bce"
    pub dataset: String,
    pub dims: Dims,
    /// ordered (name, shape)
    pub params: Vec<(String, Vec<usize>)>,
    pub n_opt: usize,
}

impl ArtifactMeta {
    pub fn multilabel(&self) -> bool {
        self.loss == "sigmoid_bce"
    }

    pub fn param_bytes(&self) -> u64 {
        self.params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>() as u64 * 4)
            .sum()
    }

    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let dims = j.req("dims");
        let gd = |k: &str| -> Result<usize> {
            dims.req(k)
                .as_usize()
                .ok_or_else(|| anyhow!("dims.{k} not a number"))
        };
        let params = j
            .req("params")
            .as_array()
            .ok_or_else(|| anyhow!("params not an array"))?
            .iter()
            .map(|p| {
                let name = p.req("name").as_str().unwrap_or_default().to_string();
                let shape: Vec<usize> = p
                    .req("shape")
                    .as_array()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect();
                (name, shape)
            })
            .collect();
        Ok(ArtifactMeta {
            name: j.req("name").as_str().unwrap_or_default().to_string(),
            file: j.req("file").as_str().unwrap_or_default().to_string(),
            kind: j.req("kind").as_str().unwrap_or_default().to_string(),
            arch: j.req("arch").as_str().unwrap_or_default().to_string(),
            optimizer: j.req("optimizer").as_str().unwrap_or_default().to_string(),
            loss: j.req("loss").as_str().unwrap_or_default().to_string(),
            dataset: j.req("dataset").as_str().unwrap_or_default().to_string(),
            dims: Dims {
                b: gd("b")?,
                n1: gd("n1")?,
                n2: gd("n2")?,
                d: gd("d")?,
                h: gd("h")?,
                c: gd("c")?,
                f1: gd("f1")?,
                f2: gd("f2")?,
            },
            params,
            n_opt: j.req("n_opt").as_usize().unwrap_or(0),
        })
    }
}

/// Model parameters + optimizer state, in manifest order.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<Tensor>,
    /// adam: [m.., v.., t]; sgd: empty
    pub opt: Vec<Tensor>,
}

impl ModelState {
    /// Fresh state for a train artifact (Glorot weights, zero opt state).
    pub fn init(meta: &ArtifactMeta, rng: &mut Pcg64) -> ModelState {
        let params: Vec<Tensor> = meta
            .params
            .iter()
            .map(|(_, s)| Tensor::glorot(s, rng))
            .collect();
        let opt = if meta.optimizer == "adam" {
            let mut opt: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
            opt.extend(params.iter().map(|p| Tensor::zeros(&p.shape)));
            opt.push(Tensor::zeros(&[])); // t
            opt
        } else {
            Vec::new()
        };
        ModelState { params, opt }
    }

    pub fn param_bytes(&self) -> u64 {
        self.params.iter().map(|p| p.size_bytes()).sum()
    }

    /// Elementwise average of many states' *parameters* (Alg. 2 line 12).
    /// Optimizer state is not averaged (it stays local, like FedAvg+Adam).
    pub fn average_params(states: &[&ModelState]) -> Vec<Tensor> {
        assert!(!states.is_empty());
        let mut out = states[0].params.clone();
        for t in out.iter_mut() {
            for x in t.data.iter_mut() {
                *x = 0.0;
            }
        }
        let scale = 1.0 / states.len() as f32;
        for s in states {
            for (acc, p) in out.iter_mut().zip(&s.params) {
                debug_assert_eq!(acc.shape, p.shape);
                for (a, &x) in acc.data.iter_mut().zip(&p.data) {
                    *a += x * scale;
                }
            }
        }
        out
    }

    pub fn set_params(&mut self, params: Vec<Tensor>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }
}

/// The PJRT runtime: manifest + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// executions performed (profiling)
    pub exec_count: RefCell<u64>,
}

impl Runtime {
    /// Load `dir/manifest.json`; artifacts compile lazily on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {manifest_path:?} — run `make artifacts` first to AOT-compile the models"
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut metas = HashMap::new();
        for a in j
            .req("artifacts")
            .as_array()
            .ok_or_else(|| anyhow!("manifest.artifacts missing"))?
        {
            let meta = ArtifactMeta::from_json(a)?;
            metas.insert(meta.name.clone(), meta);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            metas,
            execs: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                self.metas.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.metas.keys().map(|s| s.as_str()).collect()
    }

    /// Conventional artifact names.
    pub fn train_name(arch: &str, optimizer: &str, dataset: &str) -> String {
        format!("{arch}_{optimizer}_{dataset}")
    }

    pub fn eval_name(arch: &str, dataset: &str) -> String {
        format!("{arch}_eval_{dataset}")
    }

    fn exec(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.meta(name)?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.execs.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (so timing loops exclude compilation).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.exec(name).map(|_| ())
    }

    fn block_literals(&self, meta: &ArtifactMeta, block: &Block) -> Result<Vec<xla::Literal>> {
        let dims = &meta.dims;
        if block.b != dims.b || block.n1 != dims.n1 || block.n2 != dims.n2 {
            bail!(
                "block dims ({},{},{}) do not match artifact {} ({},{},{})",
                block.b, block.n1, block.n2, meta.name, dims.b, dims.n1, dims.n2
            );
        }
        let shaped = f32_literal;
        Ok(vec![
            shaped(&block.a1, &[dims.b, dims.n1])?,
            shaped(&block.a2, &[dims.n1, dims.n2])?,
            shaped(&block.x0, &[dims.b, dims.d])?,
            shaped(&block.x1, &[dims.n1, dims.d])?,
            shaped(&block.x2, &[dims.n2, dims.d])?,
        ])
    }

    fn label_literals(&self, meta: &ArtifactMeta, block: &Block) -> Result<Vec<xla::Literal>> {
        let dims = &meta.dims;
        let y = if meta.multilabel() {
            f32_literal(&block.y_multi, &[dims.b, dims.c])?
        } else {
            i32_literal(&block.y_class, &[dims.b])?
        };
        let mask = f32_literal(&block.mask, &[dims.b])?;
        Ok(vec![y, mask])
    }

    /// Run one train step; mutates `state` in place; returns the batch loss.
    pub fn train_step(
        &self,
        name: &str,
        state: &mut ModelState,
        block: &Block,
        lr: f32,
    ) -> Result<f32> {
        let meta = self.meta(name)?.clone();
        if meta.kind != "train" {
            bail!("{name} is not a train artifact");
        }
        let exe = self.exec(name)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(
            state.params.len() + state.opt.len() + 8,
        );
        for p in &state.params {
            inputs.push(p.to_literal()?);
        }
        for o in &state.opt {
            inputs.push(o.to_literal()?);
        }
        inputs.extend(self.block_literals(&meta, block)?);
        inputs.extend(self.label_literals(&meta, block)?);
        inputs.push(xla::Literal::scalar(lr));

        *self.exec_count.borrow_mut() += 1;
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let expect = 1 + state.params.len() + state.opt.len();
        if outs.len() != expect {
            bail!("{name}: expected {expect} outputs, got {}", outs.len());
        }
        let mut iter = outs.into_iter();
        let loss = iter.next().unwrap().to_vec::<f32>()?[0];
        for p in state.params.iter_mut() {
            p.data = iter.next().unwrap().to_vec::<f32>()?;
        }
        for o in state.opt.iter_mut() {
            o.data = iter.next().unwrap().to_vec::<f32>()?;
        }
        Ok(loss)
    }

    /// Run one eval step; returns logits `[b * c]`.
    pub fn eval_step(&self, name: &str, params: &[Tensor], block: &Block) -> Result<Vec<f32>> {
        let meta = self.meta(name)?.clone();
        if meta.kind != "eval" {
            bail!("{name} is not an eval artifact");
        }
        let exe = self.exec(name)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + 5);
        for p in params {
            inputs.push(p.to_literal()?);
        }
        inputs.extend(self.block_literals(&meta, block)?);
        *self.exec_count.borrow_mut() += 1;
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_glorot_bounds() {
        let mut rng = Pcg64::new(1);
        let t = Tensor::glorot(&[64, 32], &mut rng);
        let limit = (6.0f32 / 96.0).sqrt();
        assert!(t.data.iter().all(|&x| x.abs() <= limit));
        assert!(t.data.iter().any(|&x| x.abs() > limit * 0.5));
        let b = Tensor::glorot(&[32], &mut rng);
        assert!(b.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn average_params() {
        let a = ModelState {
            params: vec![Tensor {
                shape: vec![2],
                data: vec![1.0, 3.0],
            }],
            opt: vec![],
        };
        let b = ModelState {
            params: vec![Tensor {
                shape: vec![2],
                data: vec![3.0, 5.0],
            }],
            opt: vec![],
        };
        let avg = ModelState::average_params(&[&a, &b]);
        assert_eq!(avg[0].data, vec![2.0, 4.0]);
    }

    #[test]
    fn manifest_meta_parsing() {
        let j = Json::parse(
            r#"{"name":"gcn_sgd_tiny","file":"x.hlo.txt","kind":"train",
                "arch":"gcn","optimizer":"sgd","loss":"softmax_ce","dataset":"tiny",
                "dims":{"b":8,"n1":32,"n2":128,"d":16,"h":16,"c":4,"f1":4,"f2":4},
                "params":[{"name":"w1","shape":[16,16]},{"name":"b1","shape":[16]}],
                "n_opt":0}"#,
        )
        .unwrap();
        let m = ArtifactMeta::from_json(&j).unwrap();
        assert_eq!(m.dims.n2, 128);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.param_bytes(), (16 * 16 + 16) * 4);
        assert!(!m.multilabel());
    }
}
