//! Std-only persistent worker pool for the tiled compute kernels.
//!
//! The pool exists for exactly one call shape: "run this `Fn(thread_index)`
//! once on every pool thread, block until all of them are done"
//! ([`ThreadPool::run`]). The kernel layer maps thread indices onto disjoint
//! output-row ranges, so no synchronization beyond the completion barrier is
//! ever needed, and the float accumulation order inside each output element
//! is untouched (see `kernels.rs` for the determinism contract).
//!
//! Design notes:
//!
//! - **Persistent threads.** Workers are spawned once in [`ThreadPool::new`]
//!   and parked on an mpsc receive between calls; a kernel dispatch is two
//!   channel hops per worker, not a thread spawn. `ThreadPool::new(1)` (or a
//!   host with one core) spawns nothing and runs jobs inline.
//! - **Caller participates.** `run` executes index 0 on the calling thread,
//!   so a pool of T threads spawns only T−1 OS threads and the caller is
//!   never idle-blocked while work remains.
//! - **Scoped borrows without `std::thread::scope`.** Jobs borrow the
//!   caller's stack (kernel operands live in the caller's frame). The borrow
//!   is erased to `'static` to cross the channel and is sound because `run`
//!   does not return — not even by panic — until every worker has reported
//!   completion of that exact job.
//! - **Panic propagation.** A panicking job (on any thread, including the
//!   caller) is caught, the barrier is still drained, and the panic resumes
//!   on the caller. The pool stays usable afterwards.
//! - **Shutdown.** Dropping the pool closes the job channels; workers fall
//!   out of their receive loop and are joined. Repeated create/run/drop
//!   cycles are safe (exercised by the tests below).
//!
//! `run` is not re-entrant from inside a job: kernels never nest pool
//! dispatches, and nesting would interleave completion tokens.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A dispatched job: a `&(dyn Fn(usize) + Sync)` with its lifetime erased so
/// it can cross the worker channels. Validity is guaranteed by the
/// completion barrier in [`ThreadPool::run`].
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-called from many threads) and
// outlives every use — `run` blocks on the completion barrier before the
// borrow it was erased from can end.
unsafe impl Send for Job {}

/// `Ok(())` or the payload of a panicking job.
type JobResult = std::thread::Result<()>;

/// Number of hardware threads on this host (>= 1).
pub fn host_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Persistent worker pool; see the module docs.
pub struct ThreadPool {
    threads: usize,
    /// one job channel per spawned worker (indices `1..threads`)
    txs: Vec<Sender<Job>>,
    /// completion tokens, one per worker per job
    done_rx: Receiver<JobResult>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool of `threads` total execution lanes (caller included); `0` means
    /// auto-size to [`host_threads`]. Spawns `threads - 1` OS threads.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = if threads == 0 { host_threads() } else { threads };
        let (done_tx, done_rx) = channel::<JobResult>();
        let mut txs = Vec::with_capacity(threads.saturating_sub(1));
        let mut handles = Vec::with_capacity(threads.saturating_sub(1));
        for i in 1..threads {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("llcg-kernels-{i}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // SAFETY: the pointer stays valid until the done
                        // token below is received by `run`
                        let f = unsafe { &*job.0 };
                        let result = catch_unwind(AssertUnwindSafe(|| f(i)));
                        if done.send(result).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawning kernel pool worker");
            txs.push(tx);
            handles.push(handle);
        }
        ThreadPool {
            threads,
            txs,
            done_rx,
            handles,
        }
    }

    /// Total execution lanes (caller thread included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(t)` once for every lane `t` in `0..threads()`, blocking until
    /// all calls return. Index 0 runs on the calling thread. Panics in any
    /// lane resume on the caller after the barrier drains.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.txs.is_empty() {
            f(0);
            return;
        }
        // SAFETY: lifetime erasure only; `run` blocks on the completion
        // barrier below before returning (even under panic), so the borrow
        // outlives every worker's use of it.
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        for tx in &self.txs {
            tx.send(Job(erased as *const _))
                .expect("kernel pool worker exited early");
        }
        let caller = catch_unwind(AssertUnwindSafe(|| erased(0)));
        let mut panic = caller.err();
        for _ in 0..self.txs.len() {
            match self
                .done_rx
                .recv()
                .expect("kernel pool worker vanished mid-job")
            {
                Ok(()) => {}
                Err(p) => panic = Some(p),
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // closing the job channels ends the worker loops
        self.txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_lane_exactly_once() {
        for threads in [1usize, 2, 3, 7] {
            let pool = ThreadPool::new(threads);
            assert_eq!(pool.threads(), threads);
            let hits: Vec<AtomicUsize> = (0..threads).map(|_| AtomicUsize::new(0)).collect();
            pool.run(&|t| {
                hits[t].fetch_add(1, Ordering::SeqCst);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "lane {t} of {threads}");
            }
        }
    }

    #[test]
    fn disjoint_row_writes_land() {
        // the kernel usage pattern: each lane owns a contiguous range
        let pool = ThreadPool::new(4);
        let n = 103usize;
        let mut out = vec![0u32; n];
        let chunk = n.div_ceil(4);
        struct SendMut(*mut u32);
        unsafe impl Send for SendMut {}
        unsafe impl Sync for SendMut {}
        let base = SendMut(out.as_mut_ptr());
        pool.run(&|t| {
            let lo = t * chunk;
            if lo >= n {
                return;
            }
            let hi = (lo + chunk).min(n);
            for i in lo..hi {
                // SAFETY: ranges are disjoint per lane and in-bounds
                unsafe { *base.0.add(i) = i as u32 + 1 };
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32 + 1);
        }
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(&|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 200 * 3);
    }

    #[test]
    fn repeated_create_and_drop_is_clean() {
        for _ in 0..20 {
            let pool = ThreadPool::new(4);
            let total = AtomicUsize::new(0);
            pool.run(&|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), 4);
            drop(pool); // joins workers; must not hang or leak
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|t| {
                if t == 1 {
                    panic!("lane 1 boom");
                }
            });
        }));
        assert!(caught.is_err(), "worker panic must surface on the caller");
        // the pool remains usable after a panicking job
        let total = AtomicUsize::new(0);
        pool.run(&|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn zero_asks_for_host_threads() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), host_threads());
    }
}
