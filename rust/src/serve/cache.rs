//! Per-layer hidden-embedding cache over the full graph + the cached
//! inference engine.
//!
//! ## Why a cache
//!
//! The training-side eval path answers "scores for node v" by building a
//! full 2-hop block (`Fanout::Full`, ratio 1.0) and running the whole
//! forward — O(f1·f2) feature gathers and a layer-1 matmul *per query*, the
//! neighborhood-explosion cost the LLCG paper attributes to GNN inference.
//! But with full (capped) fanout the layer-1 hidden state of a block slot
//! depends only on the node behind the slot, so it can be computed **once
//! per snapshot for every node in the graph** and reused by every query:
//! a request for node v then needs only its cached layer-1 neighbor
//! embeddings plus one output-layer step — near-O(1) in the fanout product.
//!
//! ## Bit-parity contract
//!
//! Served scores are **bit-identical** to `driver::eval_logits` /
//! `driver::eval_split` (asserted in `tests/serve.rs`, across batch sizes,
//! kernel-thread counts, and snapshot hot-swaps). This holds because every
//! cache/query computation replays the exact FLOP sequence of the block
//! forward (`runtime::native`):
//!
//! - [`agg_row`] reproduces `matmul_banded` on a `Fanout::Full` block row:
//!   slot 0 is the node itself, then its first `f − 1` neighbors in
//!   adjacency order, weight `1/cnt`, ascending-slot accumulation. Padding
//!   slots are structural zeros the banded kernel skips.
//! - Dense layers run through the *same* tiled kernels (`linear`/`matmul`),
//!   which are per-output-row bit-identical at any row count and thread
//!   count (the kernel determinism contract, `runtime/README.md`) — so a
//!   batch of 1 and a batch of 64 produce the same rows.
//! - Elementwise combines (SAGE's two-path add, APPNP's teleport mix) are
//!   written in the block forward's exact expression order.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::graph::{CsrGraph, Dataset};
use crate::runtime::kernels::{self, add_bias, linear, matmul, relu_inplace, KernelCtx, SendMut};
use crate::runtime::native::APPNP_TELEPORT;
use crate::serve::snapshot::ModelSnapshot;

/// Snapshot parameter `i`'s data (positional, artifact order — the same
/// indexing `runtime::native` uses).
fn pd(snap: &ModelSnapshot, i: usize) -> &[f32] {
    &snap.params[i].data
}

/// One capped-mean aggregation row — the exact FLOP sequence
/// `matmul_banded` executes on a `Fanout::Full` block row for node `v`:
/// slot 0 is `v` itself, slots 1.. are its first `cap − 1` neighbors in
/// adjacency order, every filled slot weighted `1/cnt`, accumulated in
/// ascending slot order per output element.
fn agg_row(g: &CsrGraph, src: &[f32], w: usize, cap: usize, v: u32, out: &mut [f32]) {
    debug_assert!(cap >= 1);
    out.fill(0.0);
    let neigh = g.neighbors(v);
    let take = (cap - 1).min(neigh.len());
    let a = 1.0 / (1 + take) as f32;
    let srow = &src[v as usize * w..(v as usize + 1) * w];
    for (o, &x) in out.iter_mut().zip(srow) {
        *o += a * x;
    }
    for &u in &neigh[..take] {
        let srow = &src[u as usize * w..(u as usize + 1) * w];
        for (o, &x) in out.iter_mut().zip(srow) {
            *o += a * x;
        }
    }
}

/// Capped-mean aggregation for a batch of ids:
/// `out[i] = mean(src[ids[i]], src[its first cap−1 neighbors])`,
/// parallelized over disjoint output-row ranges (each row is written by
/// exactly one lane, so the result is bit-identical at any thread count).
/// The one aggregation driver — both the full-graph cache build and the
/// per-query output-layer step go through it.
fn agg_ids(
    kc: &KernelCtx,
    g: &CsrGraph,
    src: &[f32],
    w: usize,
    cap: usize,
    ids: &[u32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), ids.len() * w);
    let base = SendMut(out.as_mut_ptr());
    kernels::par_ranges(kc, ids.len(), ids.len() * cap * w, |lo, hi| {
        // SAFETY: [lo, hi) row ranges are disjoint across lanes and
        // in-bounds; par_ranges blocks until every lane returns.
        let rows = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo * w), (hi - lo) * w) };
        for (i, &v) in ids[lo..hi].iter().enumerate() {
            agg_row(g, src, w, cap, v, &mut rows[i * w..(i + 1) * w]);
        }
    });
}

/// [`agg_ids`] over every node of the graph (the cache-build pass); the id
/// vector costs one `u32` per node, negligible next to the `n`-row matmuls
/// that follow.
fn agg_full(kc: &KernelCtx, g: &CsrGraph, src: &[f32], w: usize, cap: usize, out: &mut [f32]) {
    let ids: Vec<u32> = (0..g.n as u32).collect();
    agg_ids(kc, g, src, w, cap, &ids, out);
}

/// Arch-specific cached layers. Everything a query needs beyond the output
/// parameters lives here, indexed by node id.
enum Layers {
    /// `h1[v] = relu(x_v @ w1 + b1)` — `[n, h]`
    Mlp { h1: Vec<f32> },
    /// `h1[v] = relu(mean_f2(x) @ w1 + b1)` — `[n, h]`
    Gcn { h1: Vec<f32> },
    /// `h1[v] = relu(x_v @ ws1 + mean_f2(x) @ wn1 + b1)` — `[n, h]`
    Sage { h1: Vec<f32> },
    /// `mlp_out[v] = mlp(x_v)` and the first PPR step
    /// `p1[v] = β·mlp_out[v] + (1−β)·mean_f2(mlp_out)` — each `[n, c]`
    Appnp { mlp_out: Vec<f32>, p1: Vec<f32> },
}

impl Layers {
    fn bytes(&self) -> u64 {
        let len = match self {
            Layers::Mlp { h1 } | Layers::Gcn { h1 } | Layers::Sage { h1 } => h1.len(),
            Layers::Appnp { mlp_out, p1 } => mlp_out.len() + p1.len(),
        };
        len as u64 * 4
    }
}

/// The per-snapshot hidden-embedding cache over the full graph: computed
/// once per published snapshot (invalidated on hot-swap), reused by every
/// query. See the module docs for the bit-parity contract.
pub struct EmbeddingCache {
    /// snapshot version this cache was computed from
    pub version: u64,
    /// wall-clock seconds the build took
    pub build_s: f64,
    n: usize,
    layers: Layers,
}

impl EmbeddingCache {
    /// Compute the cache for `snap` over `ds`'s full graph, on `kc`'s
    /// kernel pool. Cost: one layer-1 forward over all `n` nodes — paid
    /// once per snapshot instead of per query.
    pub fn build(snap: &ModelSnapshot, ds: &Dataset, kc: &KernelCtx) -> Result<EmbeddingCache> {
        let _s = crate::obs::span("serve.cache_build");
        let dims = snap.dims;
        let (d, h, c) = (dims.d, dims.h, dims.c);
        if ds.name != snap.dataset {
            bail!(
                "snapshot was trained on dataset {:?}, cannot serve {:?}",
                snap.dataset,
                ds.name
            );
        }
        if ds.d != d || ds.c() != c {
            bail!(
                "dataset {} is d={},c={} but snapshot expects d={d},c={c}",
                ds.name,
                ds.d,
                ds.c()
            );
        }
        let n = ds.n();
        let g = &ds.graph;
        let t0 = Instant::now();
        let layers = match snap.arch.as_str() {
            "mlp" => {
                let mut h1 = vec![0.0; n * h];
                linear(kc, &ds.features, pd(snap, 0), Some(pd(snap, 1)), &mut h1, n, d, h, true);
                Layers::Mlp { h1 }
            }
            "gcn" => {
                // agg2 = mean_f2(x); h1 = relu(agg2 @ w1 + b1)
                let mut agg2 = vec![0.0; n * d];
                agg_full(kc, g, &ds.features, d, dims.f2, &mut agg2);
                let mut h1 = vec![0.0; n * h];
                linear(kc, &agg2, pd(snap, 0), Some(pd(snap, 1)), &mut h1, n, d, h, true);
                Layers::Gcn { h1 }
            }
            "sage" => {
                // h1 = relu(x @ ws1 + mean_f2(x) @ wn1 + b1) — the block
                // forward's op order: self matmul, neighbor matmul, add,
                // bias, relu
                let mut n1v = vec![0.0; n * d];
                agg_full(kc, g, &ds.features, d, dims.f2, &mut n1v);
                let mut h1 = vec![0.0; n * h];
                matmul(kc, &ds.features, pd(snap, 0), &mut h1, n, d, h);
                let mut tmp = vec![0.0; n * h];
                matmul(kc, &n1v, pd(snap, 1), &mut tmp, n, d, h);
                for (a, &t) in h1.iter_mut().zip(&tmp) {
                    *a += t;
                }
                add_bias(&mut h1, pd(snap, 2), n, h);
                relu_inplace(&mut h1);
                Layers::Sage { h1 }
            }
            "appnp" => {
                // mlp_out = mlp(x); p1 = β·mlp_out + (1−β)·mean_f2(mlp_out)
                let mut u = vec![0.0; n * h];
                linear(kc, &ds.features, pd(snap, 0), Some(pd(snap, 1)), &mut u, n, d, h, true);
                let mut mlp_out = vec![0.0; n * c];
                linear(kc, &u, pd(snap, 2), Some(pd(snap, 3)), &mut mlp_out, n, h, c, false);
                let mut p1 = vec![0.0; n * c];
                agg_full(kc, g, &mlp_out, c, dims.f2, &mut p1);
                for (o, &hv) in p1.iter_mut().zip(&mlp_out) {
                    *o = APPNP_TELEPORT * hv + (1.0 - APPNP_TELEPORT) * *o;
                }
                Layers::Appnp { mlp_out, p1 }
            }
            other => bail!("no serving cache for arch {other:?}"),
        };
        Ok(EmbeddingCache {
            version: snap.version,
            build_s: t0.elapsed().as_secs_f64(),
            n,
            layers,
        })
    }

    /// Resident size of the cached embeddings.
    pub fn bytes(&self) -> u64 {
        self.layers.bytes()
    }

    pub fn nodes(&self) -> usize {
        self.n
    }
}

/// Reusable per-batch gather/aggregation scratch: resized (never shrunk in
/// capacity) each batch, so steady-state queries are allocation-free.
#[derive(Default)]
struct Scratch {
    gather: Vec<f32>,
    agg: Vec<f32>,
    agg2: Vec<f32>,
    hid: Vec<f32>,
    tmp: Vec<f32>,
    logits: Vec<f32>,
}

/// A snapshot bound to its embedding cache and a kernel context — the thing
/// that actually answers queries. One output-layer step per batch; scores
/// are bit-identical to the training-side eval forward.
pub struct InferenceEngine {
    snap: Arc<ModelSnapshot>,
    ds: Arc<Dataset>,
    cache: EmbeddingCache,
    kc: KernelCtx,
    scratch: Scratch,
}

impl InferenceEngine {
    /// Build the cache for `snap` and bind it. `kc` supplies the kernel
    /// pool for both the cache build and every query batch.
    pub fn new(
        snap: Arc<ModelSnapshot>,
        ds: Arc<Dataset>,
        kc: KernelCtx,
    ) -> Result<InferenceEngine> {
        let cache = EmbeddingCache::build(&snap, &ds, &kc)?;
        Ok(InferenceEngine {
            snap,
            ds,
            cache,
            kc,
            scratch: Scratch::default(),
        })
    }

    /// Snapshot version this engine serves.
    pub fn version(&self) -> u64 {
        self.snap.version
    }

    pub fn snapshot(&self) -> &Arc<ModelSnapshot> {
        &self.snap
    }

    pub fn cache(&self) -> &EmbeddingCache {
        &self.cache
    }

    /// Number of classes per score row.
    pub fn classes(&self) -> usize {
        self.snap.dims.c
    }

    /// Score a batch of nodes; returns the logits `[nodes.len() * c]`
    /// (row-major, borrowed from the engine's scratch — copy out what must
    /// outlive the next batch). Bit-identical to the eval-path forward for
    /// every row, at any batch size and kernel-thread count.
    pub fn score_batch(&mut self, nodes: &[u32]) -> Result<&[f32]> {
        let InferenceEngine {
            snap,
            ds,
            cache,
            kc,
            scratch,
        } = self;
        // only the scratch is mutated; rebind the rest as shared borrows
        let (snap, ds, cache, kc): (&ModelSnapshot, &Dataset, &EmbeddingCache, &KernelCtx) =
            (snap, ds, cache, kc);
        let dims = snap.dims;
        let (d, h, c) = (dims.d, dims.h, dims.c);
        let bn = nodes.len();
        let n = cache.n;
        for &v in nodes {
            if (v as usize) >= n {
                bail!("node {v} out of range (graph has {n} nodes)");
            }
        }
        let g = &ds.graph;
        let Scratch {
            gather,
            agg,
            agg2,
            hid,
            tmp,
            logits,
        } = scratch;
        logits.resize(bn * c, 0.0);
        if bn == 0 {
            return Ok(logits.as_slice());
        }
        match &cache.layers {
            Layers::Mlp { h1 } => {
                // logits = h1[v] @ w2 + b2
                gather.resize(bn * h, 0.0);
                for (i, &v) in nodes.iter().enumerate() {
                    gather[i * h..(i + 1) * h]
                        .copy_from_slice(&h1[v as usize * h..(v as usize + 1) * h]);
                }
                linear(kc, gather, pd(snap, 2), Some(pd(snap, 3)), logits, bn, h, c, false);
            }
            Layers::Gcn { h1 } => {
                // logits = mean_f1(h1) @ w2 + b2
                agg.resize(bn * h, 0.0);
                agg_ids(kc, g, h1, h, dims.f1, nodes, agg);
                linear(kc, agg, pd(snap, 2), Some(pd(snap, 3)), logits, bn, h, c, false);
            }
            Layers::Sage { h1 } => {
                // h0 = relu(x_v @ ws1 + mean_f1(x) @ wn1 + b1)
                // logits = h0 @ ws2 + mean_f1(h1) @ wn2 + b2
                gather.resize(bn * d, 0.0);
                for (i, &v) in nodes.iter().enumerate() {
                    gather[i * d..(i + 1) * d].copy_from_slice(ds.feature(v));
                }
                agg.resize(bn * d, 0.0);
                agg_ids(kc, g, &ds.features, d, dims.f1, nodes, agg);
                agg2.resize(bn * h, 0.0);
                agg_ids(kc, g, h1, h, dims.f1, nodes, agg2);
                hid.resize(bn * h, 0.0);
                matmul(kc, gather, pd(snap, 0), hid, bn, d, h);
                tmp.resize(bn * h, 0.0);
                matmul(kc, agg, pd(snap, 1), tmp, bn, d, h);
                for (a, &t) in hid.iter_mut().zip(tmp.iter()) {
                    *a += t;
                }
                add_bias(hid, pd(snap, 2), bn, h);
                relu_inplace(hid);
                matmul(kc, hid, pd(snap, 3), logits, bn, h, c);
                tmp.resize(bn * c, 0.0);
                matmul(kc, agg2, pd(snap, 4), tmp, bn, h, c);
                for (o, &t) in logits.iter_mut().zip(tmp.iter()) {
                    *o += t;
                }
                add_bias(logits, pd(snap, 5), bn, c);
            }
            Layers::Appnp { mlp_out, p1 } => {
                // logits = β·mlp_out[v] + (1−β)·mean_f1(p1)
                agg.resize(bn * c, 0.0);
                agg_ids(kc, g, p1, c, dims.f1, nodes, agg);
                for (i, &v) in nodes.iter().enumerate() {
                    let hrow = &mlp_out[v as usize * c..(v as usize + 1) * c];
                    let arow = &agg[i * c..(i + 1) * c];
                    let orow = &mut logits[i * c..(i + 1) * c];
                    for ((o, &hv), &av) in orow.iter_mut().zip(hrow).zip(arow) {
                        *o = APPNP_TELEPORT * hv + (1.0 - APPNP_TELEPORT) * av;
                    }
                }
            }
        }
        Ok(logits.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::runtime::{ModelState, Runtime};
    use crate::util::Pcg64;

    #[test]
    fn cache_rejects_mismatched_dataset() {
        let (rt, _) = Runtime::load_or_native("target/native-artifacts").unwrap();
        let meta = rt.meta("gcn_adam_tiny").unwrap().clone();
        let mut rng = Pcg64::new(1);
        let state = ModelState::init(&meta, &mut rng);
        let snap = ModelSnapshot::for_artifact(&meta, &state.params, 1).unwrap();
        let wrong = generators::by_name("tiny-hetero", 0).unwrap();
        let kc = KernelCtx::new(1);
        let err = EmbeddingCache::build(&snap, &wrong, &kc).unwrap_err();
        assert!(format!("{err:#}").contains("tiny"), "{err:#}");
    }

    #[test]
    fn agg_row_matches_banded_block_row() {
        // independent oracle: build a Fanout::Full block and compare the
        // banded aggregation of its A2 row against agg_row for the same node
        use crate::runtime::kernels::matmul_ref;
        use crate::sampler::{BlockBuilder, Fanout};

        let ds = generators::by_name("tiny", 0).unwrap();
        let mut bb = BlockBuilder::new(4, 3, 4, ds.d, ds.c(), false);
        bb.fanout = Fanout::Full;
        let mut rng = Pcg64::new(5);
        let targets = [7u32, 20, 33, 41];
        let blk = bb.build(&targets, &ds.graph, &ds, &mut rng);
        // dense reference: full A1 @ x1 row per target (f1-capped mean)
        let mut want = vec![0.0f32; blk.b * ds.d];
        matmul_ref(&blk.a1, &blk.x1, &mut want, blk.b, blk.n1, ds.d);
        for (i, &t) in targets.iter().enumerate() {
            let mut got = vec![f32::NAN; ds.d];
            agg_row(&ds.graph, &ds.features, ds.d, 3, t, &mut got);
            let wrow = &want[i * ds.d..(i + 1) * ds.d];
            assert_eq!(
                wrow.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "target {t}"
            );
        }
    }
}
