//! Deterministic load generator for the inference server: closed-loop
//! (each client issues its next request the moment the previous one
//! answers) and open-loop (requests arrive on a fixed-rate schedule
//! regardless of completion — queueing delay shows up in the latency tail).
//!
//! The *workload* is deterministic — the request node sequence is drawn
//! from a seeded [`Pcg64`], so two runs at the same seed issue the same
//! queries in the same per-client order. The measured latencies are of
//! course not; they are the whole point.
//!
//! Latency accounting:
//! - closed loop: response time (send → reply) per request;
//! - open loop: *scheduled-arrival* to reply — a backlogged server shows up
//!   as growing tail latency, exactly as it would for real traffic.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::serve::server::ServerClient;
use crate::util::stats::{mean, Percentiles};
use crate::util::Pcg64;

/// Arrival discipline of the generated load.
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    /// each client issues requests back-to-back (measures peak sustainable
    /// throughput)
    Closed,
    /// requests arrive at `rate_rps` on a fixed schedule shared by all
    /// clients (measures behavior under a target offered load)
    Open { rate_rps: f64 },
}

/// One load-test specification.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    pub mode: LoadMode,
    /// concurrent client threads
    pub clients: usize,
    /// total requests to issue
    pub requests: usize,
    /// workload seed (node sequence is reproducible from it)
    pub seed: u64,
}

/// What a load run measured.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    pub requests: usize,
    pub completed: usize,
    pub errors: usize,
    pub wall_s: f64,
    /// completed requests per second of wall-clock
    pub throughput_rps: f64,
    pub mean_ms: f64,
    /// latency percentiles in milliseconds (NaN when nothing completed)
    pub latency: Percentiles,
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests ({} ok, {} err) in {:.3}s -> {:.1} req/s; \
             latency ms: mean={:.3} p50={:.3} p95={:.3} p99={:.3}",
            self.requests,
            self.completed,
            self.errors,
            self.wall_s,
            self.throughput_rps,
            self.mean_ms,
            self.latency.p50,
            self.latency.p95,
            self.latency.p99
        )
    }
}

/// Run one load test against `client`, drawing request nodes uniformly from
/// `nodes` with the spec's seed. Blocks until every request has answered.
pub fn run_load(client: &ServerClient, nodes: &[u32], spec: &LoadSpec) -> LoadReport {
    assert!(!nodes.is_empty(), "run_load needs a non-empty node set");
    let requests = spec.requests;
    let clients = spec.clients.max(1);
    let mut rng = Pcg64::new(spec.seed);
    let seq: Vec<u32> = (0..requests).map(|_| *rng.choose(nodes)).collect();

    let start = Instant::now();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
    let mut errors = 0usize;
    match spec.mode {
        LoadMode::Closed => {
            let chunk = requests.div_ceil(clients).max(1);
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for ch in seq.chunks(chunk) {
                    let c = client.clone();
                    handles.push(s.spawn(move || {
                        let mut lats = Vec::with_capacity(ch.len());
                        let mut errs = 0usize;
                        for &v in ch {
                            let t0 = Instant::now();
                            match c.query(v) {
                                Ok(_) => lats.push(t0.elapsed().as_secs_f64() * 1e3),
                                Err(_) => errs += 1,
                            }
                        }
                        (lats, errs)
                    }));
                }
                for h in handles {
                    let (lats, errs) = h.join().expect("load client panicked");
                    lat_ms.extend(lats);
                    errors += errs;
                }
            });
        }
        LoadMode::Open { rate_rps } => {
            let rate = rate_rps.max(1e-3);
            let next = AtomicUsize::new(0);
            let collected: Mutex<(Vec<f64>, usize)> =
                Mutex::new((Vec::with_capacity(requests), 0));
            std::thread::scope(|s| {
                for _ in 0..clients {
                    let c = client.clone();
                    let next = &next;
                    let collected = &collected;
                    let seq = &seq;
                    s.spawn(move || loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= requests {
                            break;
                        }
                        let due = start + Duration::from_secs_f64(i as f64 / rate);
                        if let Some(wait) = due.checked_duration_since(Instant::now()) {
                            std::thread::sleep(wait);
                        }
                        let r = c.query(seq[i]);
                        // latency from the *scheduled* arrival: lateness
                        // (all clients busy) counts as queueing delay
                        let lat = due.elapsed().as_secs_f64() * 1e3;
                        let mut g = collected.lock().expect("load collector poisoned");
                        match r {
                            Ok(_) => g.0.push(lat),
                            Err(_) => g.1 += 1,
                        }
                    });
                }
            });
            let (l, e) = collected.into_inner().expect("load collector poisoned");
            lat_ms = l;
            errors = e;
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    let completed = lat_ms.len();
    let latency = if lat_ms.is_empty() {
        Percentiles {
            p50: f64::NAN,
            p90: f64::NAN,
            p95: f64::NAN,
            p99: f64::NAN,
        }
    } else {
        Percentiles::of(&lat_ms)
    };
    LoadReport {
        requests,
        completed,
        errors,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        mean_ms: mean(&lat_ms),
        latency,
    }
}
