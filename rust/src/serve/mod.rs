//! `llcg::serve` — online GNN inference on top of trained LLCG models.
//!
//! Four pieces (see `rust/src/serve/README.md` for the full contract):
//!
//! - [`snapshot`] — immutable [`ModelSnapshot`]s (params + arch +
//!   block-format normalization metadata) and the [`SnapshotHub`], the
//!   atomic publish point a still-training run feeds at round boundaries
//!   (`Run::publish_to`) so a live server hot-swaps improving models.
//! - [`cache`] — the per-snapshot [`EmbeddingCache`]: layer-1 hidden
//!   embeddings for *every* node of the graph, computed once per snapshot
//!   on the tiled kernel layer. A query then needs only its cached
//!   layer-1 neighbor embeddings plus one output-layer step — near-O(1)
//!   instead of the O(f1·f2) 2-hop recomputation the eval path pays per
//!   request. [`InferenceEngine`] binds a snapshot to its cache and scores
//!   batches **bit-identically** to `driver::eval_split`.
//! - [`server`] — the micro-batching [`Server`]: bounded request queue,
//!   deadline-or-batch-size flush, per-request [`NodeScores`] replies, and
//!   cache invalidation on snapshot hot-swap.
//! - [`loadgen`] — deterministic closed/open-loop load generation with
//!   latency percentiles ([`run_load`] → [`LoadReport`]).
//!
//! ```text
//! training (either engine)          serving
//!   round r ends                      clients ──▶ bounded queue
//!     └─ publish(θ_r) ──▶ SnapshotHub ──▶ dispatcher: micro-batch,
//!                          ▲ version++     rebuild cache on version change,
//!                          │               one output-layer step per batch
//!                          └── llcg serve / examples/serve_pipeline.rs
//! ```

pub mod cache;
pub mod loadgen;
pub mod server;
pub mod snapshot;

pub use cache::{EmbeddingCache, InferenceEngine};
pub use loadgen::{run_load, LoadMode, LoadReport, LoadSpec};
pub use server::{NodeScores, QueryError, ServeConfig, ServeStats, Server, ServerClient};
pub use snapshot::{ModelSnapshot, SnapshotHub, SnapshotPublisher};
