//! The online inference server: a bounded request queue, a dispatcher
//! thread that flushes micro-batches on a deadline-or-batch-size rule, and
//! cheap cloneable client handles.
//!
//! ```text
//!   clients ──SyncSender<Req>──▶ dispatcher (owns InferenceEngine + pool)
//!     ▲                             │ collect until max_batch or flush_us
//!     └────── per-request reply ◀───┘ score_batch → NodeScores per request
//! ```
//!
//! Micro-batching amortizes the output-layer matmul across concurrent
//! requests (the kernels are per-row bit-identical, so batching never
//! changes a score — only the clock). The queue is bounded
//! ([`ServeConfig::queue`]), so overload applies backpressure at the
//! sender instead of growing memory.
//!
//! Hot-swap: before executing each batch the dispatcher compares the
//! [`SnapshotHub`] version against its engine's; when a training run has
//! published a newer snapshot, the embedding cache is rebuilt and the batch
//! (and everything after it) is served from the new model. In-flight
//! requests of the previous batch keep their already-computed scores — a
//! swap never tears a batch.

use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::graph::Dataset;
use crate::metrics;
use crate::obs;
use crate::runtime::{KernelCtx, ThreadPool};
use crate::serve::cache::InferenceEngine;
use crate::serve::snapshot::SnapshotHub;

/// Serving knobs; every field is also an `ExperimentConfig` key
/// (`serve_batch` / `serve_flush_us` / `serve_threads` / `serve_queue` /
/// `serve_shed`), so `llcg serve` takes them from the same schema as
/// everything else.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// flush a micro-batch at this many queued requests
    pub max_batch: usize,
    /// ... or this many microseconds after its first request, whichever
    /// comes first
    pub flush_us: u64,
    /// kernel-pool lanes for cache builds + batch execution (0 = all cores)
    pub threads: usize,
    /// bounded request-queue depth (senders block when full — backpressure)
    pub queue: usize,
    /// load-shedding: when the queue is full, reject the query immediately
    /// with [`QueryError::Overloaded`] instead of blocking the sender
    pub shed: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 32,
            flush_us: 200,
            threads: 0,
            queue: 1024,
            shed: false,
        }
    }
}

impl ServeConfig {
    /// Pull the serve keys out of an experiment config.
    pub fn from_experiment(cfg: &ExperimentConfig) -> ServeConfig {
        ServeConfig {
            max_batch: cfg.serve_batch,
            flush_us: cfg.serve_flush_us,
            threads: cfg.serve_threads,
            queue: cfg.serve_queue,
            shed: cfg.serve_shed,
        }
    }
}

/// One answered query: per-class scores (logits) for a node, plus the
/// snapshot version that served it (so clients can observe hot-swaps).
#[derive(Clone, Debug)]
pub struct NodeScores {
    pub node: u32,
    /// snapshot version the scores came from
    pub version: u64,
    /// argmax class (first-max tie-break, as `metrics::argmax`)
    pub pred: u32,
    /// raw per-class logits, length `c`
    pub scores: Vec<f32>,
}

enum Req {
    Query {
        node: u32,
        /// when the client enqueued the request — queue-wait time is
        /// `enq.elapsed()` at flush, recorded in the `serve.queue_wait_s`
        /// histogram
        enq: Instant,
        reply: Sender<std::result::Result<NodeScores, String>>,
    },
    Shutdown,
}

/// Why a query was not answered. A real enum rather than a boxed message
/// because the vendored `anyhow` shim has no downcasting: shed-aware
/// clients must be able to tell "back off and retry" ([`Overloaded`])
/// apart from a hard failure by matching, not by parsing strings.
///
/// [`Overloaded`]: QueryError::Overloaded
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QueryError {
    /// shed mode only: the bounded request queue was full and the query was
    /// rejected without blocking — retry later or slow down
    Overloaded,
    /// anything terminal: server shut down, node id out of range, engine
    /// failure while scoring the batch
    Failed(String),
}

impl QueryError {
    pub fn is_overloaded(&self) -> bool {
        matches!(self, QueryError::Overloaded)
    }
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::Overloaded => write!(f, "serve: overloaded (queue full, request shed)"),
            QueryError::Failed(msg) => write!(f, "serve: {msg}"),
        }
    }
}

// gives `client.query(..)?` in `anyhow::Result` contexts the blanket
// `From<E: std::error::Error>` conversion of the shim
impl std::error::Error for QueryError {}

/// Dispatcher-side counters, readable via [`Server::stats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// snapshot hot-swaps (cache rebuilds) performed
    pub swaps: u64,
    /// published snapshots the server could not build a cache for (it keeps
    /// serving the previous snapshot; see the dispatcher's swap rule)
    pub failed_swaps: u64,
    /// largest micro-batch executed
    pub max_batch: usize,
    /// requests rejected before batching (out-of-range node id)
    pub rejected: u64,
    /// requests shed at the queue in [`ServeConfig::shed`] mode (the queue
    /// was full; the client got [`QueryError::Overloaded`] immediately)
    pub shed: u64,
}

impl ServeStats {
    /// Mean executed micro-batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// The live counters behind [`ServeStats`]: per-server relaxed atomics, so
/// the dispatcher's flush hot path and every client's shed path update them
/// without a lock (the old `Mutex<ServeStats>` serialized clients against
/// the dispatcher on overload). [`Server::stats`] reads them into the same
/// `ServeStats` snapshot as before.
#[derive(Default)]
struct ServeShared {
    requests: obs::Counter,
    batches: obs::Counter,
    swaps: obs::Counter,
    failed_swaps: obs::Counter,
    max_batch: obs::Counter,
    rejected: obs::Counter,
    shed: obs::Counter,
}

impl ServeShared {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            requests: self.requests.get(),
            batches: self.batches.get(),
            swaps: self.swaps.get(),
            failed_swaps: self.failed_swaps.get(),
            max_batch: self.max_batch.get() as usize,
            rejected: self.rejected.get(),
            shed: self.shed.get(),
        }
    }
}

/// A running inference server. Create client handles with
/// [`Server::client`]; stop it with [`Server::shutdown`].
pub struct Server {
    tx: SyncSender<Req>,
    shed: bool,
    stats: Arc<ServeShared>,
    handle: Option<JoinHandle<()>>,
}

/// Cheap cloneable handle for issuing queries; safe to share across client
/// threads. In [`ServeConfig::shed`] mode a full queue rejects the query
/// with [`QueryError::Overloaded`] instead of blocking.
#[derive(Clone)]
pub struct ServerClient {
    tx: SyncSender<Req>,
    shed: bool,
    stats: Arc<ServeShared>,
}

impl ServerClient {
    /// Score one node (blocks until the micro-batch containing this request
    /// flushes — except in shed mode, where a full queue returns
    /// [`QueryError::Overloaded`] without enqueueing). Fails if the node id
    /// is out of range or the server has shut down.
    pub fn query(&self, node: u32) -> std::result::Result<NodeScores, QueryError> {
        let (reply_tx, reply_rx) = channel();
        let req = Req::Query {
            node,
            enq: Instant::now(),
            reply: reply_tx,
        };
        if self.shed {
            match self.tx.try_send(req) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.stats.shed.inc();
                    return Err(QueryError::Overloaded);
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(QueryError::Failed("server has shut down".into()));
                }
            }
        } else if self.tx.send(req).is_err() {
            return Err(QueryError::Failed("server has shut down".into()));
        }
        match reply_rx.recv() {
            Ok(Ok(scores)) => Ok(scores),
            Ok(Err(msg)) => Err(QueryError::Failed(msg)),
            Err(_) => Err(QueryError::Failed(
                "server dropped the request (shutting down?)".into(),
            )),
        }
    }
}

impl Server {
    /// Start a server over the hub's current snapshot. Fails if nothing has
    /// been published yet or the cache build fails; a training run that
    /// keeps publishing to `hub` hot-swaps the model under live traffic.
    pub fn start(hub: Arc<SnapshotHub>, ds: Arc<Dataset>, cfg: ServeConfig) -> Result<Server> {
        if hub.current().is_none() {
            bail!("serve: no snapshot published yet (run training with a publisher first)");
        }
        if cfg.max_batch == 0 || cfg.queue == 0 {
            bail!("serve: max_batch and queue must be >= 1");
        }
        let (tx, rx) = sync_channel::<Req>(cfg.queue);
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let stats = Arc::new(ServeShared::default());
        let stats2 = stats.clone();
        let handle = std::thread::Builder::new()
            .name("llcg-serve".into())
            .spawn(move || dispatcher(hub, ds, cfg, rx, stats2, ready_tx))
            .expect("spawning serve dispatcher");
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Server {
                tx,
                shed: cfg.shed,
                stats,
                handle: Some(handle),
            }),
            Ok(Err(msg)) => {
                let _ = handle.join();
                bail!("serve: {msg}");
            }
            Err(_) => {
                let _ = handle.join();
                bail!("serve: dispatcher died during startup");
            }
        }
    }

    pub fn client(&self) -> ServerClient {
        ServerClient {
            tx: self.tx.clone(),
            shed: self.shed,
            stats: self.stats.clone(),
        }
    }

    /// Snapshot of the dispatcher counters.
    pub fn stats(&self) -> ServeStats {
        self.stats.snapshot()
    }

    /// Stop the dispatcher (pending and queued requests error out) and join
    /// its thread.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            // best-effort: if the queue is momentarily full, detach instead
            // of risking a blocked drop (shutdown() is the orderly path)
            if self.tx.try_send(Req::Shutdown).is_ok() {
                let _ = h.join();
            }
        }
    }
}

type Batch = Vec<(u32, Instant, Sender<std::result::Result<NodeScores, String>>)>;

fn dispatcher(
    hub: Arc<SnapshotHub>,
    ds: Arc<Dataset>,
    cfg: ServeConfig,
    rx: Receiver<Req>,
    stats: Arc<ServeShared>,
    ready: Sender<std::result::Result<(), String>>,
) {
    // one persistent pool for the whole server lifetime: cache rebuilds on
    // hot-swap reuse it instead of respawning threads
    let pool = Arc::new(ThreadPool::new(cfg.threads));
    let kc = KernelCtx::with_pool(pool, false);
    let snap = hub.current().expect("checked by Server::start");
    let mut engine = match InferenceEngine::new(snap, ds.clone(), kc.clone()) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    let n = ds.n();
    let flush_after = Duration::from_micros(cfg.flush_us);
    let mut batch: Batch = Vec::with_capacity(cfg.max_batch);
    // version of the last published snapshot whose cache build failed —
    // skipped until the hub moves again, so one bad publish costs one
    // rebuild attempt, not one per batch
    let mut failed_swap: u64 = 0;
    let admit = |req: Req, batch: &mut Batch| -> Option<()> {
        // None = shutdown requested
        match req {
            Req::Shutdown => None,
            Req::Query { node, enq, reply } => {
                if (node as usize) >= n {
                    stats.rejected.inc();
                    let _ = reply.send(Err(format!("node {node} out of range (n={n})")));
                } else {
                    batch.push((node, enq, reply));
                }
                Some(())
            }
        }
    };

    'serve: loop {
        batch.clear();
        // block for the batch's first request
        while batch.is_empty() {
            match rx.recv() {
                Err(_) => break 'serve,
                Ok(req) => {
                    if admit(req, &mut batch).is_none() {
                        break 'serve;
                    }
                }
            }
        }
        // deadline-or-batch-size collection window
        let deadline = Instant::now() + flush_after;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => {
                    if admit(req, &mut batch).is_none() {
                        flush(&hub, &ds, &kc, &mut engine, &mut batch, &stats, &mut failed_swap);
                        break 'serve;
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    flush(&hub, &ds, &kc, &mut engine, &mut batch, &stats, &mut failed_swap);
                    break 'serve;
                }
            }
        }
        flush(&hub, &ds, &kc, &mut engine, &mut batch, &stats, &mut failed_swap);
    }
}

/// Execute one micro-batch: hot-swap the engine if the hub moved, score the
/// batch, answer every request.
#[allow(clippy::too_many_arguments)]
fn flush(
    hub: &SnapshotHub,
    ds: &Arc<Dataset>,
    kc: &KernelCtx,
    engine: &mut InferenceEngine,
    batch: &mut Batch,
    stats: &ServeShared,
    failed_swap: &mut u64,
) {
    if batch.is_empty() {
        return;
    }
    let _flush_span = obs::span("serve.flush");
    // hot-swap: rebuild the cache when training published a newer snapshot.
    // A snapshot whose cache cannot be built (wrong dataset/dims on a
    // shared hub) is recorded in `failed_swap` and skipped until the hub
    // moves again — the server keeps answering from the engine it has.
    let hub_v = hub.version();
    if hub_v != engine.version() && hub_v != *failed_swap {
        if let Some(snap) = hub.current() {
            // judge by the fetched snapshot's own version, not hub_v: a
            // publish racing between the two reads must not be re-attempted
            // (or double-counted) on the next batch
            let snap_v = snap.version;
            if snap_v != engine.version() && snap_v != *failed_swap {
                let t_swap = Instant::now();
                let built = {
                    let _s = obs::span("serve.swap_rebuild");
                    InferenceEngine::new(snap, ds.clone(), kc.clone())
                };
                match built {
                    Ok(fresh) => {
                        *engine = fresh;
                        *failed_swap = 0;
                        stats.swaps.inc();
                        obs::histogram("serve.cache_rebuild_s")
                            .record_s(t_swap.elapsed().as_secs_f64());
                    }
                    Err(e) => {
                        *failed_swap = snap_v;
                        stats.failed_swaps.inc();
                        eprintln!(
                            "serve: snapshot v{snap_v} rejected ({e:#}); \
                             continuing on v{}",
                            engine.version()
                        );
                    }
                }
            }
        }
    }
    let c = engine.classes();
    let version = engine.version();
    let nodes: Vec<u32> = batch.iter().map(|(v, _, _)| *v).collect();
    stats.requests.add(nodes.len() as u64);
    stats.batches.inc();
    stats.max_batch.record_max(nodes.len() as u64);
    // queue wait = client enqueue → just before the batch computes
    let qw = obs::histogram("serve.queue_wait_s");
    for (_, enq, _) in batch.iter() {
        qw.record_s(enq.elapsed().as_secs_f64());
    }
    let t_compute = Instant::now();
    let scored = {
        let _s = obs::span("serve.batch_compute");
        engine.score_batch(&nodes)
    };
    obs::histogram("serve.batch_compute_s").record_s(t_compute.elapsed().as_secs_f64());
    match scored {
        Ok(scores) => {
            for (i, (node, _, reply)) in batch.drain(..).enumerate() {
                let row = &scores[i * c..(i + 1) * c];
                let _ = reply.send(Ok(NodeScores {
                    node,
                    version,
                    pred: metrics::argmax(row) as u32,
                    scores: row.to_vec(),
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for (_, _, reply) in batch.drain(..) {
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client_over(tx: SyncSender<Req>, shed: bool) -> (ServerClient, Arc<ServeShared>) {
        let stats = Arc::new(ServeShared::default());
        (
            ServerClient {
                tx,
                shed,
                stats: stats.clone(),
            },
            stats,
        )
    }

    #[test]
    fn shed_mode_rejects_on_full_queue_without_blocking() {
        // a queue of depth 1, pre-filled, with no dispatcher draining it: a
        // blocking client would hang here forever, a shedding one must
        // return Overloaded immediately and count it
        let (tx, rx) = sync_channel::<Req>(1);
        tx.send(Req::Shutdown).expect("pre-fill");
        let (client, stats) = client_over(tx, true);
        let err = client.query(3).expect_err("queue is full");
        assert_eq!(err, QueryError::Overloaded);
        assert!(err.is_overloaded());
        assert_eq!(stats.snapshot().shed, 1);
        // draining the queue makes room again; the next failure is the
        // missing dispatcher (reply channel dies), not overload
        drop(rx.recv().expect("the pre-filled request"));
        drop(rx);
        match client.query(3).expect_err("no dispatcher") {
            QueryError::Failed(_) => {}
            QueryError::Overloaded => panic!("room in the queue, must not shed"),
        }
        assert_eq!(stats.snapshot().shed, 1, "hard failures are not sheds");
    }

    #[test]
    fn non_shed_client_reports_shutdown_as_failed() {
        let (tx, rx) = sync_channel::<Req>(1);
        drop(rx);
        let (client, stats) = client_over(tx, false);
        let err = client.query(0).expect_err("server gone");
        assert!(matches!(err, QueryError::Failed(_)));
        assert!(!err.is_overloaded());
        assert_eq!(stats.snapshot().shed, 0);
    }

    #[test]
    fn query_error_displays_and_converts() {
        assert!(QueryError::Overloaded.to_string().contains("overloaded"));
        assert!(QueryError::Failed("boom".into()).to_string().contains("boom"));
        // the `?` bridge into anyhow contexts must keep working
        let e: anyhow::Error = QueryError::Overloaded.into();
        assert!(format!("{e:#}").contains("overloaded"));
    }
}
