//! Immutable model snapshots + the atomic publish/subscribe hub that feeds
//! them from a (possibly still-running) training run into a live inference
//! server.
//!
//! A [`ModelSnapshot`] freezes everything the serving path needs to answer
//! node-prediction queries: the parameter tensors, the architecture, and the
//! normalization metadata of the block format (the `f1`/`f2` fanout caps
//! that define the capped-mean aggregation — see `sampler::BlockBuilder`).
//! Snapshots are validated against the artifact's parameter specs at
//! construction, so a live server can trust every snapshot it receives.
//!
//! The [`SnapshotHub`] is the hand-off point: training publishes an improving
//! snapshot at every round boundary (`Run::publish_to` wires this through
//! both execution engines), the server reads the current one with a single
//! cheap `Arc` clone, and versions are strictly monotonic so consumers can
//! detect a hot-swap without comparing tensors.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::runtime::native::{param_specs, NATIVE_ARCHS};
use crate::runtime::{ArtifactMeta, Dims, Tensor};

/// An immutable, self-describing trained model: parameters + architecture +
/// the block-format normalization metadata (dims incl. the `f1`/`f2` fanout
/// caps). `version` is assigned by [`SnapshotHub::publish`] (0 = never
/// published).
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// monotonically increasing publish counter (0 until published)
    pub version: u64,
    /// training round that produced these parameters
    pub round: usize,
    pub arch: String,
    pub dataset: String,
    /// sigmoid-BCE (multilabel) vs softmax-CE head
    pub multilabel: bool,
    /// block-format dims: `d`/`h`/`c` widths plus the `f1`/`f2` fanout caps
    /// that define the capped-mean neighbor aggregation
    pub dims: Dims,
    /// parameter tensors, in the artifact's positional order
    pub params: Vec<Tensor>,
}

impl ModelSnapshot {
    /// Freeze `params` (positional, artifact order) for serving. Validates
    /// the arch against the native model zoo (serving executes on the
    /// native kernels; GAT is PJRT-only) and every parameter shape against
    /// the artifact's specs.
    pub fn for_artifact(
        meta: &ArtifactMeta,
        params: &[Tensor],
        round: usize,
    ) -> Result<ModelSnapshot> {
        if !NATIVE_ARCHS.contains(&meta.arch.as_str()) {
            bail!(
                "serving supports the native model zoo {:?}; arch {:?} is PJRT-only",
                NATIVE_ARCHS,
                meta.arch
            );
        }
        let specs = param_specs(&meta.arch, meta.dims.d, meta.dims.h, meta.dims.c)?;
        if params.len() != specs.len()
            || specs.iter().zip(params).any(|((_, s), t)| *s != t.shape)
        {
            bail!(
                "snapshot params do not match artifact {} (want {:?}, got {:?})",
                meta.name,
                specs,
                params.iter().map(|t| t.shape.clone()).collect::<Vec<_>>()
            );
        }
        Ok(ModelSnapshot {
            version: 0,
            round,
            arch: meta.arch.clone(),
            dataset: meta.dataset.clone(),
            multilabel: meta.multilabel(),
            dims: meta.dims,
            params: params.to_vec(),
        })
    }

    pub fn param_bytes(&self) -> u64 {
        self.params.iter().map(|t| t.size_bytes()).sum()
    }
}

/// The atomic snapshot hand-off between a training run and a live server.
///
/// `publish` swaps the current snapshot under a short lock and bumps the
/// version; `current` hands out an `Arc` clone, so readers never block
/// training for more than the pointer swap and a served request keeps its
/// snapshot alive even while a newer one replaces it (hot-swap without
/// tearing).
#[derive(Debug, Default)]
pub struct SnapshotHub {
    slot: Mutex<Option<Arc<ModelSnapshot>>>,
    version: AtomicU64,
}

impl SnapshotHub {
    pub fn new() -> Arc<SnapshotHub> {
        Arc::new(SnapshotHub::default())
    }

    /// Install `snap` as the current snapshot; assigns and returns its
    /// version (strictly increasing across publishes).
    pub fn publish(&self, mut snap: ModelSnapshot) -> u64 {
        let mut slot = self.slot.lock().expect("snapshot hub poisoned");
        let v = self.version.load(Ordering::SeqCst) + 1;
        snap.version = v;
        *slot = Some(Arc::new(snap));
        // stored under the slot lock so version() == current().version once
        // the new snapshot is visible
        self.version.store(v, Ordering::SeqCst);
        v
    }

    /// The current snapshot, if anything has been published yet.
    pub fn current(&self) -> Option<Arc<ModelSnapshot>> {
        self.slot.lock().expect("snapshot hub poisoned").clone()
    }

    /// Version of the current snapshot (0 = nothing published). Cheap —
    /// the server polls this per micro-batch to detect hot-swaps.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }
}

/// Round-boundary publisher handed to a run (`Run::publish_to`): snapshots
/// the freshly averaged/corrected global parameters into a [`SnapshotHub`]
/// after every round, on whichever engine executes the run. The artifact is
/// validated once here so a mid-run publish cannot fail.
#[derive(Clone, Debug)]
pub struct SnapshotPublisher {
    hub: Arc<SnapshotHub>,
    meta: ArtifactMeta,
}

impl SnapshotPublisher {
    pub fn new(hub: Arc<SnapshotHub>, meta: &ArtifactMeta) -> Result<SnapshotPublisher> {
        if !NATIVE_ARCHS.contains(&meta.arch.as_str()) {
            bail!(
                "cannot publish serving snapshots for arch {:?} (native zoo: {:?})",
                meta.arch,
                NATIVE_ARCHS
            );
        }
        Ok(SnapshotPublisher {
            hub,
            meta: meta.clone(),
        })
    }

    /// Publish `params` as round `round`'s snapshot; returns the version.
    pub fn publish(&self, round: usize, params: &[Tensor]) -> u64 {
        let snap = ModelSnapshot::for_artifact(&self.meta, params, round)
            .expect("publisher validated the artifact at construction");
        self.hub.publish(snap)
    }

    pub fn hub(&self) -> &Arc<SnapshotHub> {
        &self.hub
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelState, Runtime};
    use crate::util::Pcg64;

    fn tiny_meta() -> ArtifactMeta {
        let (rt, _) = Runtime::load_or_native("target/native-artifacts").unwrap();
        rt.meta("gcn_adam_tiny").unwrap().clone()
    }

    #[test]
    fn snapshot_validates_params_and_arch() {
        let meta = tiny_meta();
        let mut rng = Pcg64::new(1);
        let state = ModelState::init(&meta, &mut rng);
        let snap = ModelSnapshot::for_artifact(&meta, &state.params, 3).unwrap();
        assert_eq!(snap.round, 3);
        assert_eq!(snap.version, 0, "unpublished snapshots carry version 0");
        assert_eq!(snap.dims.f1, meta.dims.f1);
        // wrong tensor count is rejected
        assert!(ModelSnapshot::for_artifact(&meta, &state.params[..2], 0).is_err());
        // PJRT-only arch is rejected
        let mut gat = meta.clone();
        gat.arch = "gat".into();
        assert!(ModelSnapshot::for_artifact(&gat, &state.params, 0).is_err());
        assert!(SnapshotPublisher::new(SnapshotHub::new(), &gat).is_err());
    }

    #[test]
    fn hub_versions_are_monotonic_and_swap_atomically() {
        let meta = tiny_meta();
        let mut rng = Pcg64::new(2);
        let a = ModelState::init(&meta, &mut rng);
        let b = ModelState::init(&meta, &mut rng);
        let hub = SnapshotHub::new();
        assert_eq!(hub.version(), 0);
        assert!(hub.current().is_none());
        let v1 = hub.publish(ModelSnapshot::for_artifact(&meta, &a.params, 1).unwrap());
        assert_eq!((v1, hub.version()), (1, 1));
        let held = hub.current().unwrap();
        assert_eq!(held.version, 1);
        let v2 = hub.publish(ModelSnapshot::for_artifact(&meta, &b.params, 2).unwrap());
        assert_eq!((v2, hub.version()), (2, 2));
        // the old snapshot stays alive for whoever held it (no tearing)
        assert_eq!(held.version, 1);
        assert_eq!(held.params[0].data, a.params[0].data);
        assert_eq!(hub.current().unwrap().params[0].data, b.params[0].data);
    }

    #[test]
    fn publisher_round_trip() {
        let meta = tiny_meta();
        let mut rng = Pcg64::new(3);
        let state = ModelState::init(&meta, &mut rng);
        let hub = SnapshotHub::new();
        let p = SnapshotPublisher::new(hub.clone(), &meta).unwrap();
        assert_eq!(p.publish(1, &state.params), 1);
        assert_eq!(p.publish(2, &state.params), 2);
        assert_eq!(p.hub().current().unwrap().round, 2);
    }
}
